"""Version shims for the pinned container jax.

``jax.shard_map`` (and its ``check_vma`` kwarg) landed after 0.4.x; older
releases ship the same function as ``jax.experimental.shard_map.shard_map``
with the kwarg spelled ``check_rep``.  Call sites import ``shard_map`` from
here and always use the new spelling.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _NEW_API = True
except AttributeError:                     # pragma: no cover - env dependent
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEW_API = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if _NEW_API:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
