"""Roofline-term derivation from compiled dry-run artifacts.

Per DESIGN.md §6: ``cost_analysis()`` on the SPMD-partitioned executable
reports *per-device* FLOPs and bytes (verified by probe); collective bytes
are summed from the compiled HLO text (per-device operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).

  t_compute    = flops / PEAK_FLOPS
  t_memory     = bytes / HBM_BW
  t_collective = coll_bytes / ICI_BW

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (we
use 49.5e9).  The dominant term is the projected bottleneck; MODEL_FLOPS /
HLO_FLOPs measures useful-compute fraction (catches remat/dispatch waste).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 49.5e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_census(hlo_text: str) -> dict[str, dict]:
    """Per-op-kind {count, bytes} from compiled HLO (result-shape bytes,
    per device; ``-done`` ops skipped so start/done pairs count once)."""
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += _shape_bytes(shape_text)
    return out


@dataclass
class RooflineTerms:
    flops: float
    bytes_hbm: float
    bytes_coll: float
    model_flops: float = 0.0
    collectives: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_coll / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        if self.flops <= 0:
            return 0.0
        return self.model_flops / self.flops

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_hbm,
            "coll_bytes_per_device": self.bytes_coll,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops_per_device": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "collectives": self.collectives,
        }


def analyze(compiled, model_flops_per_device: float = 0.0) -> RooflineTerms:
    cost = compiled.cost_analysis() or {}
    census = collective_census(compiled.as_text())
    coll_bytes = sum(v["bytes"] for v in census.values())
    return RooflineTerms(
        flops=float(cost.get("flops", 0.0)),
        bytes_hbm=float(cost.get("bytes accessed", 0.0)),
        bytes_coll=float(coll_bytes),
        model_flops=model_flops_per_device,
        collectives=census,
    )
