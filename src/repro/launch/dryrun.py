import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input-shape) cell on the production
meshes — (16, 16) single-pod and (2, 16, 16) two-pod — with
ShapeDtypeStruct inputs (no allocation), printing memory_analysis() (the
fits-proof) and cost_analysis() + a collective census (the §Roofline
inputs).

Roofline accuracy vs compile time: XLA prices while-loop bodies once, but
fully unrolling a 48-layer MoE train step takes the SPMD partitioner tens
of minutes.  So each looped cell compiles THREE ways:

  1. the production scan version at full depth — the fits/shardability
     proof and the memory analysis;
  2. two shallow *unrolled* probes at pattern-complete depths (multiples of
     ``global_every`` so the local:global attention mix is preserved; k=2/4
     onboarded users for the CF burst) — their cost/census difference gives
     exact per-layer (per-user) terms;
  3. roofline terms = fixed + per_layer x L, extrapolated component-wise
     (FLOPs, HBM bytes, per-collective bytes/counts).

Everything loop-free (LM decode, GNN, recsys, CF build) is analysed
directly from the full compile.

Usage:
  python -m repro.launch.dryrun --all                  # every cell, both meshes
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --multi-pod
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import get_arch, list_archs
from repro.configs.base import ArchSpec, ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineTerms, analyze
from repro.launch.steps import build_cell, jit_cell


def _compile(spec: ArchSpec, shape: ShapeSpec, mesh, unroll: bool):
    cell = build_cell(spec, shape, mesh, unroll=unroll)
    with mesh:
        lowered = jit_cell(cell, mesh).lower(*cell.args)
        compiled = lowered.compile()
    return cell, compiled


def _probe_depths(cfg) -> tuple[int, int]:
    unit = cfg.global_every or 1
    if unit == 1:
        return 1, 3
    return unit, 2 * unit


def _extrapolate(t_a: RooflineTerms, t_b: RooflineTerms, xa: int, xb: int,
                 x: int, model_flops: float) -> RooflineTerms:
    def lerp(a: float, b: float) -> float:
        per = (b - a) / (xb - xa)
        return max(a, a + per * (x - xa))

    kinds = set(t_a.collectives) | set(t_b.collectives)
    census = {}
    for k in kinds:
        ca = t_a.collectives.get(k, {"count": 0, "bytes": 0})
        cb = t_b.collectives.get(k, {"count": 0, "bytes": 0})
        census[k] = {"count": int(round(lerp(ca["count"], cb["count"]))),
                     "bytes": int(round(lerp(ca["bytes"], cb["bytes"])))}
    return RooflineTerms(
        flops=lerp(t_a.flops, t_b.flops),
        bytes_hbm=lerp(t_a.bytes_hbm, t_b.bytes_hbm),
        bytes_coll=lerp(t_a.bytes_coll, t_b.bytes_coll),
        model_flops=model_flops,
        collectives=census,
    )


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_path: str | None = None, verbose: bool = True) -> dict:
    spec = get_arch(arch_id)
    shape = spec.shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind}

    if shape_name in spec.skip_shapes:
        rec.update(status="skipped", reason=spec.skip_shapes[shape_name])
        _emit(rec, out_path, verbose)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size

        # 1. Full-depth scan compile: shardability proof + memory analysis.
        cell, compiled = _compile(spec, shape, mesh, unroll=False)
        mem = compiled.memory_analysis()
        t_full = time.time() - t0

        # 2/3. Roofline terms (extrapolated where the cell loops).
        method = "direct"
        mf_dev = cell.model_flops / n_dev
        if spec.family == "lm" and shape.kind in ("train", "prefill"):
            la, lb = _probe_depths(spec.config)
            sa = dataclasses.replace(
                spec, config=dataclasses.replace(spec.config, n_layers=la))
            sb = dataclasses.replace(
                spec, config=dataclasses.replace(spec.config, n_layers=lb))
            _, ca = _compile(sa, shape, mesh, unroll=True)
            _, cb = _compile(sb, shape, mesh, unroll=True)
            terms = _extrapolate(analyze(ca), analyze(cb), la, lb,
                                 spec.config.n_layers, mf_dev)
            method = f"layer-extrapolated[{la},{lb}]"
        elif spec.family == "cf" and shape.kind == "onboard":
            ka, kb = 2, 4
            dims = dict(shape.dims)
            shp_a = ShapeSpec(shape.name, shape.kind,
                              {**dims, "k_new": ka})
            shp_b = ShapeSpec(shape.name, shape.kind,
                              {**dims, "k_new": kb})
            _, ca = _compile(spec, shp_a, mesh, unroll=True)
            _, cb = _compile(spec, shp_b, mesh, unroll=True)
            terms = _extrapolate(analyze(ca), analyze(cb), ka, kb,
                                 shape.dim("k_new"), mf_dev)
            method = f"user-extrapolated[{ka},{kb}]"
        else:
            terms = analyze(compiled, mf_dev)

        rec.update(
            status="ok",
            n_devices=n_dev,
            compile_s=round(time.time() - t0, 1),
            full_compile_s=round(t_full, 1),
            method=method,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            },
            roofline=terms.as_dict(),
        )
    except Exception as e:                              # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _emit(rec, out_path, verbose)
    return rec


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024 or unit == "TB":
            return f"{n:.2f}{unit}"
        n /= 1024
    return f"{n:.2f}TB"


def _emit(rec: dict, out_path: str | None, verbose: bool) -> None:
    if verbose:
        tag = f"[{rec['arch']}/{rec['shape']}@{rec['mesh']}]"
        if rec["status"] == "skipped":
            print(f"{tag} SKIP: {rec['reason']}", flush=True)
        elif rec["status"] == "error":
            print(f"{tag} ERROR: {rec['error']}", flush=True)
        else:
            m = rec["memory"]
            r = rec["roofline"]
            print(f"{tag} ok {rec['compile_s']}s ({rec['method']}) | "
                  f"per-device: args={_fmt_bytes(m['argument_bytes'])} "
                  f"temp={_fmt_bytes(m['temp_bytes'])} "
                  f"out={_fmt_bytes(m['output_bytes'])} | "
                  f"flops={r['flops_per_device']:.3e} "
                  f"t_comp={r['t_compute_s']*1e3:.2f}ms "
                  f"t_mem={r['t_memory_s']*1e3:.2f}ms "
                  f"t_coll={r['t_collective_s']*1e3:.2f}ms "
                  f"dom={r['dominant']} useful={r['useful_fraction']:.2f}",
                  flush=True)
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        slim = {k: v for k, v in rec.items() if k != "traceback"}
        with open(out_path, "a") as f:
            f.write(json.dumps(slim) + "\n")


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch_id in list_archs():
        spec = get_arch(arch_id)
        for shape in spec.shapes:
            cells.append((arch_id, shape.name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or not args.single_pod:
        meshes.append(True)

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_err = 0
    for arch_id, shape_name in cells:
        for mp in meshes:
            rec = run_cell(arch_id, shape_name, mp, out_path=args.out)
            n_err += rec["status"] == "error"
    if n_err:
        raise SystemExit(f"{n_err} cells failed")
    print("dry-run complete: all cells ok")


if __name__ == "__main__":
    main()
