"""Cell builder: (arch spec, shape, mesh) -> jit-ready step function with
input structs + sharding trees.  This is the single dispatch point the
dry-run, the trainer and the benchmarks all share.

Train cells lower the *full* train step (loss -> backward -> AdamW update
with ZeRO-sharded optimizer state) so the gradient-synchronisation and
optimizer collectives are part of the compiled artifact being analysed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.distributed import sharding as shd
from repro.models import cf as cf_mod
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as lm_mod
from repro.training.optimizer import AdamW, AdamWState


@dataclass
class Cell:
    name: str
    fn: Callable
    args: tuple                      # ShapeDtypeStruct pytrees
    in_specs: Any                    # PartitionSpec pytrees (tuple matching args)
    out_specs: Any                   # or None for auto
    donate: tuple[int, ...]
    model_flops: float               # analytic useful FLOPs (whole step, global)


def _opt_structs_and_specs(param_structs, param_specs, ax):
    opt = AdamW(lr=3e-4, weight_decay=0.01)
    opt_structs = jax.eval_shape(opt.init, param_structs)

    def ext(spec, struct):
        return shd.zero_extend(spec, struct.shape, ax)

    opt_specs = AdamWState(
        step=P(),
        mu=jax.tree.map(ext, param_specs, param_structs,
                        is_leaf=lambda x: isinstance(x, P)),
        nu=jax.tree.map(ext, param_specs, param_structs,
                        is_leaf=lambda x: isinstance(x, P)),
        master=jax.tree.map(ext, param_specs, param_structs,
                            is_leaf=lambda x: isinstance(x, P)),
    )
    return opt, opt_structs, opt_specs


def _train_step(loss_fn, optimizer):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss
    return step


# ---------------------------------------------------------------------------
# Analytic useful-FLOPs models (global, whole step; coarse ±20% — the
# roofline's useful-fraction denominator, not a benchmark number)
# ---------------------------------------------------------------------------

def lm_model_flops(cfg, shape: ShapeSpec) -> float:
    N = cfg.active_param_count()
    B, S = shape.dim("global_batch"), shape.dim("seq_len")
    if shape.kind == "train":
        return 6.0 * N * B * S
    if shape.kind == "prefill":
        return 2.0 * N * B * S
    return 2.0 * N * B                   # decode: one token per sequence


def gnn_model_flops(cfg, shape: ShapeSpec) -> float:
    H, F = cfg.n_heads, cfg.d_hidden
    d = shape.dim("d_feat")
    C = cfg.n_classes
    if shape.kind == "train_full":
        N, E = shape.dim("n_nodes"), shape.dim("n_edges") + shape.dim(
            "n_nodes")
        fwd = 2 * N * d * H * F + 2 * N * H * F * H * C + \
            4 * E * H * (F + C)
        return 3.0 * fwd
    if shape.kind == "train_sampled":
        B = shape.dim("batch_nodes")
        f1, f2 = shape.dim("fanout")
        n1 = B * (1 + f1)
        fwd = 2 * n1 * (1 + f2) * d * H * F + 2 * B * (1 + f1) * H * F * \
            H * C
        return 3.0 * fwd
    Bt = shape.dim("batch")
    n, e = shape.dim("n_nodes"), shape.dim("n_edges") + shape.dim("n_nodes")
    fwd = Bt * (2 * n * d * H * F + 2 * n * H * F * H * C + 4 * e * H *
                (F + C))
    return 3.0 * fwd


def recsys_model_flops(cfg, shape: ShapeSpec) -> float:
    B = shape.dim("batch")
    if shape.kind == "retrieval":
        B = shape.dim("n_candidates")
    D, m = cfg.embed_dim, cfg.n_sparse
    if cfg.variant == "xdeepfm":
        cin = 0
        prev = m
        for h in cfg.cin_layers:
            cin += prev * m * D + 2 * prev * m * h * D
            prev = h
        dnn_in = m * D + cfg.n_dense
        dnn = 2 * (dnn_in * cfg.mlp_dims[0] +
                   sum(a * b for a, b in zip(cfg.mlp_dims,
                                             cfg.mlp_dims[1:])))
        fwd = B * (cin + dnn)
    elif cfg.variant == "autoint":
        T = m + cfg.n_dense
        A = cfg.d_attn
        per = 4 * T * D * A + 2 * T * T * A * 2
        fwd = B * (cfg.n_attn_layers * per + T * A * 2)
    elif cfg.variant == "bst":
        S = cfg.seq_len + 1
        attn = 4 * S * D * D + 4 * S * S * D + 8 * D * D * S
        flat = (S + m) * D
        mlp = 2 * (flat * cfg.mlp_dims[0] +
                   sum(a * b for a, b in zip(cfg.mlp_dims,
                                             cfg.mlp_dims[1:])))
        fwd = B * (attn + mlp)
    else:                                # two_tower
        dims = cfg.tower_mlp
        u_in, i_in = 128 + 4 * 32, 128 + 2 * 32
        tower = 2 * (u_in * dims[0] + i_in * dims[0] +
                     2 * sum(a * b for a, b in zip(dims, dims[1:])))
        fwd = B * tower
        if shape.kind == "train":
            fwd += 2 * B * B * dims[-1]
        if shape.kind == "retrieval":
            fwd += 2 * B * dims[-1]
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * fwd


def cf_model_flops(cfg, shape: ShapeSpec) -> float:
    n, m = shape.dim("n_users"), shape.dim("n_items")
    if shape.kind == "build":
        return 2.0 * n * n * m
    k = shape.dim("k_new")
    # Paper Sec 3.2: O((1 + (k-1)/125) * m * n) for the burst.
    return 2.0 * n * m * (1.0 + (k - 1) / cfg.set0_divisor)


# ---------------------------------------------------------------------------
# Family cell builders
# ---------------------------------------------------------------------------

def _lm_cell(spec: ArchSpec, shape: ShapeSpec, ax: shd.MeshAxes,
             unroll: bool = False, mesh=None) -> Cell:
    cfg = spec.config
    sh = shd.lm_shardings(cfg, ax, shape.kind, shape.dim("global_batch"),
                          shape.dim("seq_len"))
    if sh["hooks"].moe_ep is not None:
        sh["hooks"] = sh["hooks"]._replace(
            moe_ep=sh["hooks"].moe_ep._replace(mesh=mesh))
    pstructs = lm_mod.param_structs(cfg)
    pspecs = sh["params"]
    hooks = sh["hooks"]
    inputs = lm_mod.input_structs(cfg, shape)
    flops = lm_model_flops(cfg, shape)

    if shape.kind == "train":
        opt, ostructs, ospecs = _opt_structs_and_specs(pstructs, pspecs, ax)
        step = _train_step(
            lambda p, b: lm_mod.lm_loss(p, b["tokens"], cfg, hooks,
                                        unroll=unroll), opt)
        return Cell(
            name=f"{spec.arch_id}/{shape.name}", fn=step,
            args=(pstructs, ostructs, inputs),
            in_specs=(pspecs, ospecs, sh["inputs"]),
            out_specs=(pspecs, ospecs, P()),
            donate=(0, 1), model_flops=flops)
    if shape.kind == "prefill":
        def step(params, batch):
            return lm_mod.prefill(params, batch["tokens"], cfg, hooks,
                                  unroll=unroll)
        return Cell(
            name=f"{spec.arch_id}/{shape.name}", fn=step,
            args=(pstructs, inputs),
            in_specs=(pspecs, sh["inputs"]),
            out_specs=(P(ax.dp, ax.mp), sh["cache"]),
            donate=(), model_flops=flops)
    # decode
    def step(params, cache, tokens, pos):
        return lm_mod.decode_step(params, cache, tokens, pos, cfg, hooks)
    return Cell(
        name=f"{spec.arch_id}/{shape.name}", fn=step,
        args=(pstructs, inputs["cache"], inputs["tokens"], inputs["pos"]),
        in_specs=(pspecs, sh["inputs"]["cache"], sh["inputs"]["tokens"],
                  sh["inputs"]["pos"]),
        out_specs=(sh["logits"], sh["inputs"]["cache"]),
        donate=(1,), model_flops=flops)


def _gnn_cell(spec: ArchSpec, shape: ShapeSpec, ax: shd.MeshAxes,
              unroll: bool = False, mesh=None) -> Cell:
    cfg = spec.config
    sh = shd.gnn_shardings(cfg, ax, shape.kind)
    d = shape.dim("d_feat")
    n_out = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
             "molecule": 2}.get(shape.name, cfg.n_classes)
    pstructs = jax.eval_shape(
        lambda: gnn_mod.init_params(jax.random.PRNGKey(0), cfg, d, n_out))
    pspecs = jax.tree.map(lambda _: P(), pstructs)
    inputs = gnn_mod.input_structs(cfg, shape)
    if shape.kind == "train_full":
        # Edge-parallel shard_map formulation (§Perf Cell B): messages stay
        # local to their edge shard; node aggregates psum.
        from repro.models.gnn_ep import GNNEPInfo, loss_full_ep
        info = GNNEPInfo(axes=ax.all, mesh=mesh)
        sh["inputs"]["feats"] = P(None, None)      # replicated feature store
        loss = lambda p, b, c: loss_full_ep(p, b, c, info)   # noqa: E731
    else:
        loss = gnn_mod.LOSS_BY_KIND[shape.kind]
    opt, ostructs, ospecs = _opt_structs_and_specs(pstructs, pspecs, ax)
    step = _train_step(lambda p, b: loss(p, b, cfg), opt)
    return Cell(
        name=f"{spec.arch_id}/{shape.name}", fn=step,
        args=(pstructs, ostructs, inputs),
        in_specs=(pspecs, ospecs, sh["inputs"]),
        out_specs=(pspecs, ospecs, P()),
        donate=(0, 1), model_flops=gnn_model_flops(cfg, shape))


def _recsys_cell(spec: ArchSpec, shape: ShapeSpec, ax: shd.MeshAxes,
                 unroll: bool = False, mesh=None) -> Cell:
    cfg = spec.config
    pstructs = jax.eval_shape(
        lambda: rec_mod.init_params(jax.random.PRNGKey(0), cfg))
    sh = shd.recsys_shardings(cfg, ax, shape.kind, pstructs)
    pspecs = sh["params"]
    inputs = rec_mod.input_structs(cfg, shape)
    in_specs = {k: sh["inputs"][k] for k in inputs}
    flops = recsys_model_flops(cfg, shape)
    name = f"{spec.arch_id}/{shape.name}"

    if shape.kind == "train":
        opt, ostructs, ospecs = _opt_structs_and_specs(pstructs, pspecs, ax)
        step = _train_step(lambda p, b: rec_mod.loss(p, b, cfg), opt)
        return Cell(name=name, fn=step, args=(pstructs, ostructs, inputs),
                    in_specs=(pspecs, ospecs, in_specs),
                    out_specs=(pspecs, ospecs, P()), donate=(0, 1),
                    model_flops=flops)
    if shape.kind == "retrieval" and cfg.variant == "two_tower":
        def step(params, batch):
            return rec_mod.retrieve(params, batch, cfg)
        return Cell(name=name, fn=step, args=(pstructs, inputs),
                    in_specs=(pspecs, in_specs), out_specs=None,
                    donate=(), model_flops=flops)

    def step(params, batch):
        return rec_mod.forward(params, batch, cfg)
    return Cell(name=name, fn=step, args=(pstructs, inputs),
                in_specs=(pspecs, in_specs), out_specs=None, donate=(),
                model_flops=flops)


def _cf_cell(spec: ArchSpec, shape: ShapeSpec, ax: shd.MeshAxes,
             unroll: bool = False, mesh=None) -> Cell:
    cfg = spec.config
    sh = shd.cf_shardings(cfg, ax, shape.kind)
    inputs = cf_mod.input_structs(cfg, shape)
    flops = cf_model_flops(cfg, shape)
    name = f"{spec.arch_id}/{shape.name}"
    if shape.kind == "build":
        def step(R):
            return cf_mod.build_step(R, block_spec=sh["block"],
                                     rows_spec=sh["rows"])
        return Cell(name=name, fn=step, args=(inputs["R"],),
                    in_specs=(sh["inputs"]["R"],), out_specs=sh["out"],
                    donate=(), model_flops=flops)

    def step(state, R_new, probes):
        return cf_mod.onboard_step(state, R_new, probes, cfg, unroll=unroll,
                                   mesh_info=(ax.all, mesh))
    return Cell(name=name, fn=step,
                args=(inputs["state"], inputs["R_new"], inputs["probes"]),
                in_specs=(sh["inputs"]["state"], sh["inputs"]["R_new"],
                          sh["inputs"]["probes"]),
                out_specs=None, donate=(), model_flops=flops)


_BUILDERS = {"lm": _lm_cell, "gnn": _gnn_cell, "recsys": _recsys_cell,
             "cf": _cf_cell}


def build_cell(spec: ArchSpec, shape: ShapeSpec,
               mesh: jax.sharding.Mesh, unroll: bool = False) -> Cell:
    """``unroll=True`` (dry-run) unrolls every scan so cost analysis and
    the collective census count all iterations (XLA prices while-loop
    bodies once)."""
    ax = shd.mesh_axes(mesh)
    return _BUILDERS[spec.family](spec, shape, ax, unroll, mesh)


def jit_cell(cell: Cell, mesh: jax.sharding.Mesh):
    """Wrap the cell into a jit with NamedShardings bound to ``mesh``."""
    in_sh = shd.named(mesh, cell.in_specs)
    out_sh = shd.named(mesh, cell.out_specs) if cell.out_specs is not None \
        else None
    kwargs: dict[str, Any] = {"in_shardings": in_sh}
    if out_sh is not None:
        kwargs["out_shardings"] = out_sh
    if cell.donate:
        kwargs["donate_argnums"] = cell.donate
    return jax.jit(cell.fn, **kwargs)
