"""Production mesh construction.

Axis roles: ``pod`` (inter-pod DCN-ish axis), ``data`` (intra-pod data
parallel), ``model`` (tensor/expert parallel).  Constructed lazily as a
function so importing this module never touches jax device state — the
dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def _mk(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:             # pre-0.5 jax: Auto is the only mode
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) = 256 chips/pod single-pod; (2, 16, 16) = 512 chips over
    two pods.  Requires that many (possibly host-platform) devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Tiny mesh with the same axis roles (pytest-sized: 8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)
