"""Production training launcher.

Selects an architecture config, builds the sharded train step on the
requested mesh, and runs the restartable loop with checkpointing, straggler
monitoring and optional gradient compression.  On this CPU container it is
exercised with ``--debug-mesh`` and reduced dims by the integration tests;
on a fleet the same entry point runs under ``jax.distributed`` (one process
per host initialises before mesh construction).

  python -m repro.launch.train --arch gemma3-1b --shape train_4k \
      --steps 100 --ckpt /ckpt/run1 [--multi-pod] [--resume]
"""
from __future__ import annotations

import argparse
import logging

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import build_cell, jit_cell
from repro.training import (StragglerMonitor, TrainLoopConfig, checkpoint,
                            run_loop)

log = logging.getLogger("repro.launch.train")


def make_batches(spec, shape):
    """Deterministic host data pipeline per family."""
    if spec.family == "lm":
        from repro.data import TokenPipeline
        pipe = TokenPipeline(spec.config.vocab_size,
                             shape.dim("global_batch"),
                             shape.dim("seq_len"), seed=0)
        return lambda i: {"tokens": jnp.asarray(pipe(i)["tokens"])}
    if spec.family == "recsys":
        from repro.data import CTRStream, TwoTowerStream
        cls = (TwoTowerStream if spec.config.variant == "two_tower"
               else CTRStream)
        stream = cls(spec.config, shape.dim("batch"), seed=0)
        return lambda i: {k: jnp.asarray(v) for k, v in stream(i).items()}
    raise ValueError(f"no training pipeline for family {spec.family}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="tiny mesh (needs XLA host-device override)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    if jax.process_count() > 1:                 # fleet entry (jax.distributed
        log.info("multi-process run: %d processes", jax.process_count())

    spec = get_arch(args.arch)
    shape = spec.shape(args.shape)
    mesh = (make_debug_mesh(multi_pod=args.multi_pod) if args.debug_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    cell = build_cell(spec, shape, mesh)
    step = jit_cell(cell, mesh)

    # Materialise params + optimizer state on the mesh.
    pstructs, ostructs, _ = cell.args
    pspecs, ospecs, _ = cell.in_specs
    with mesh:
        params = jax.jit(
            lambda: jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), pstructs),
            out_shardings=shd.named(mesh, pspecs))()
        opt_state = jax.jit(
            lambda: jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), ostructs),
            out_shardings=shd.named(mesh, ospecs))()

    batches = make_batches(spec, shape)
    monitor = StragglerMonitor()

    def wrapped(params, opt_state, _ef, batch):
        with mesh:
            params, opt_state, loss = step(params, opt_state, batch)
        return params, opt_state, _ef, {"loss": loss}

    loop_cfg = TrainLoopConfig(n_steps=args.steps, ckpt_dir=args.ckpt,
                               resume=args.resume)
    run_loop(wrapped, params, opt_state, batches, loop_cfg, monitor=monitor)
    log.info("done; straggler stats: %s", monitor.stats())


if __name__ == "__main__":
    main()
