"""Serving launcher: the CF recommendation service (the paper's system) or
an LM decode service, on a chosen mesh or single host.

  python -m repro.launch.serve --service cf --users 2000 --items 800
  python -m repro.launch.serve --service lm --arch gemma3-1b --n-new 16
"""
from __future__ import annotations

import argparse
import logging

import numpy as np

log = logging.getLogger("repro.launch.serve")


def serve_cf(args) -> None:
    from repro.data import plant_twins, synth_ratings
    from repro.serving import CFServer, ServerConfig
    R = synth_ratings(0, args.users, args.items, args.users * 45)
    srv = CFServer(R, ServerConfig(capacity_extra=args.capacity,
                                   c_probes=args.probes))
    log.info("CF service up: %d users, %d items", args.users, args.items)
    burst = plant_twins(R, 8, source_user=3)
    for i in range(8):
        res = srv.onboard_user(burst[i])
        log.info("onboard %d twin=%s %.1fms", res.user_id, res.twin_found,
                 res.latency_ms)
    log.info("stats: %s", srv.stats.summary())


def serve_lm(args) -> None:
    import dataclasses
    import jax
    from repro.configs import get_arch
    from repro.models import transformer as lm
    from repro.serving import LMServer
    spec = get_arch(args.arch)
    cfg = dataclasses.replace(spec.config, n_layers=2, d_model=128,
                              n_heads=4, n_kv_heads=1, head_dim=32,
                              d_ff=256, vocab_size=1024,
                              window=(64 if spec.config.window else None))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    srv = LMServer(params, cfg, max_len=128)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)
    batch = prompts[[0, 1, 0, 1, 0]]
    out, info = srv.generate(batch, n_new=args.n_new)
    log.info("generated %s; dedup savings %.0f%% (prefilled %d/%d rows)",
             out.shape, 100 * info["dedup_savings"], info["prefill_rows"],
             info["batch"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", choices=["cf", "lm"], default="cf")
    ap.add_argument("--users", type=int, default=2000)
    ap.add_argument("--items", type=int, default=800)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--probes", type=int, default=8)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--n-new", type=int, default=8)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    (serve_cf if args.service == "cf" else serve_lm)(args)


if __name__ == "__main__":
    main()
