"""Fault-tolerant checkpointing.

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (keyed by
its flattened path) + ``meta.json`` (step, leaf manifest with per-leaf
CRC32 checksums, data-pipeline state).  Writes are atomic (tmp dir +
rename) so a crash mid-save never corrupts the latest checkpoint;
``keep_last`` prunes old steps; restore accepts a target sharding pytree
so a checkpoint taken on one mesh loads onto a different mesh shape
(elastic resize after node loss).

Restore verifies every leaf against its recorded checksum: a torn or
bit-flipped leaf raises ``CorruptCheckpointError``, and the default
newest-first restore *falls back to the previous step* instead of loading
garbage — a corrupt checkpoint costs recency, never correctness.
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import zlib
from typing import Any

import numpy as np

import jax

log = logging.getLogger(__name__)


class CorruptCheckpointError(RuntimeError):
    """A checkpoint leaf failed its CRC32 / load check."""


def _leaf_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save(ckpt_dir: str, step: int, tree: Any,
         extra: dict | None = None, keep_last: int = 3) -> str:
    """Atomically persist ``tree`` (any pytree of arrays) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # Sweep stale tmp dirs from crashed saves (any step, not just ours):
    # discovery already ignores them (the step_<n> pattern excludes .tmp),
    # so they are dead weight that would otherwise accumulate forever.
    for name in os.listdir(ckpt_dir):
        if re.fullmatch(r"step_\d+\.tmp", name):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {}
    for path, leaf in leaves:
        key = _leaf_key(path)
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if arr.dtype.kind not in "fiub" or dtype == "bfloat16":
            # non-native dtypes (bfloat16) persist as fp32 + a dtype tag
            arr = arr.astype(np.float32)
        np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "dtype": dtype,
                         "crc32": zlib.crc32(
                             np.ascontiguousarray(arr).tobytes())}
    meta = {"step": step, "manifest": manifest, "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        # Re-save at an existing step (e.g. crash recovery converging on
        # the same sequence number): drop the old dir so the rename lands.
        shutil.rmtree(final, ignore_errors=True)
    os.replace(tmp, final)                  # atomic publish

    _prune(ckpt_dir, keep_last)
    return final


def _prune(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def _restore_step(ckpt_dir: str, template: Any, step: int,
                  shardings: Any) -> tuple[Any, int, dict]:
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    try:
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(f"{d}: unreadable meta.json: {e!r}")
    manifest = meta["manifest"]

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, tmpl), shard in zip(paths, shard_leaves):
        key = _leaf_key(path)
        entry = manifest[key]
        fname = entry["file"] if isinstance(entry, dict) else entry
        try:
            arr = np.load(os.path.join(d, fname))
        except (OSError, ValueError) as e:       # missing or torn .npy
            raise CorruptCheckpointError(f"{d}: leaf {key!r} unloadable: "
                                         f"{e!r}")
        if isinstance(entry, dict) and "crc32" in entry:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != entry["crc32"]:
                raise CorruptCheckpointError(
                    f"{d}: leaf {key!r} checksum mismatch "
                    f"(got {crc:#010x}, want {entry['crc32']:#010x})")
        val = jax.numpy.asarray(arr)
        if hasattr(tmpl, "dtype"):
            val = val.astype(tmpl.dtype)
        leaves.append(jax.device_put(val, shard) if shard is not None
                      else val)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta["step"], meta.get("extra", {})


def restore(ckpt_dir: str, template: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Load into the structure of ``template``.  ``shardings`` (optional
    pytree of NamedSharding) re-lays the arrays onto the current mesh —
    checkpoints are mesh-shape agnostic.

    With ``step=None`` (the default), tries the newest step first and
    falls back to earlier steps if a leaf fails its CRC32 check; raises
    ``CorruptCheckpointError`` only when *every* step is corrupt.  An
    explicit ``step`` is loaded strictly — corruption raises."""
    if step is not None:
        return _restore_step(ckpt_dir, template, step, shardings)
    steps = all_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    last_err: CorruptCheckpointError | None = None
    for s in reversed(steps):
        try:
            return _restore_step(ckpt_dir, template, s, shardings)
        except CorruptCheckpointError as e:
            log.warning("checkpoint step %d corrupt, falling back to the "
                        "previous step: %s", s, e)
            last_err = e
    raise CorruptCheckpointError(
        f"all {len(steps)} checkpoints under {ckpt_dir} are corrupt "
        f"(last error: {last_err})")
