"""Train-step assembly: loss -> grads -> (optional compression) -> optimizer,
with gradient-accumulation microbatching so global batch is independent of
per-device memory, and a restartable outer loop with checkpoint/straggler
hooks (used by ``launch/train.py`` and the integration tests)."""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import checkpoint as ckpt_lib
from repro.training.compression import EFState, compress, init_ef
from repro.training.elastic import Action, StragglerMonitor

log = logging.getLogger("repro.train")


def make_train_step(loss_fn: Callable, optimizer, *,
                    accum_steps: int = 1,
                    compress_frac: float | None = None) -> Callable:
    """loss_fn(params, batch) -> scalar.  Returns
    step(params, opt_state, ef_state, batch) ->
        (params, opt_state, ef_state, metrics).

    With accum_steps > 1 the batch's leading axis is split into microbatches
    scanned sequentially; gradients average across them (XLA overlaps each
    microbatch's grad all-reduce with the next microbatch's compute).
    """
    grad_fn = jax.value_and_grad(loss_fn)

    def step(params, opt_state, ef_state, batch):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                tot, g = carry
                l, gi = grad_fn(params, mb)
                return (tot + l, jax.tree.map(jnp.add, g, gi)), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), zeros),
                                            micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        if compress_frac is not None:
            grads, ef_state = compress(grads, ef_state, compress_frac)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss}
        return params, opt_state, ef_state, metrics

    return step


@dataclass
class TrainLoopConfig:
    n_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    resume: bool = True


def run_loop(step_fn: Callable, params, opt_state, batches, cfg:
             TrainLoopConfig, *, ef_state: EFState | None = None,
             monitor: StragglerMonitor | None = None,
             data_state_fn: Callable[[int], dict] | None = None):
    """Restartable training loop.

    ``batches`` is a callable step -> batch (deterministic, so resuming at
    step k replays the exact data order).  Returns (params, opt_state,
    history).  On resume, the latest checkpoint's step is the start point
    and already-consumed data is skipped by construction.
    """
    start = 0
    if cfg.resume and cfg.ckpt_dir:
        latest = ckpt_lib.latest_step(cfg.ckpt_dir)
        if latest is not None:
            (params, opt_state), start, _extra = ckpt_lib.restore(
                cfg.ckpt_dir, (params, opt_state))
            log.info("resumed from step %d", start)

    if ef_state is None:
        ef_state = init_ef(params)
    monitor = monitor or StragglerMonitor()
    history = []
    for step in range(start, cfg.n_steps):
        monitor.step_started()
        batch = batches(step)
        params, opt_state, ef_state, metrics = step_fn(
            params, opt_state, ef_state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        action = monitor.step_finished()
        if step % cfg.log_every == 0:
            log.info("step %d loss %.4f", step, loss)
        if cfg.ckpt_dir and ((step + 1) % cfg.ckpt_every == 0
                             or step + 1 == cfg.n_steps
                             or action != Action.CONTINUE):
            extra = data_state_fn(step + 1) if data_state_fn else {}
            ckpt_lib.save(cfg.ckpt_dir, step + 1, (params, opt_state),
                          extra=extra, keep_last=cfg.keep_last)
        if action == Action.CHECKPOINT_AND_SHRINK:
            log.warning("straggler policy tripped at step %d: checkpointed; "
                        "relaunch with a shrunk mesh", step)
            break
        if action == Action.ABORT:
            raise RuntimeError(f"step {step} exceeded hang timeout")
    return params, opt_state, history
