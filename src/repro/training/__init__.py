from repro.training.optimizer import AdamW, SGD, AdamWState, warmup_cosine
from repro.training.train_loop import (TrainLoopConfig, make_train_step,
                                       run_loop)
from repro.training import checkpoint
from repro.training.compression import compress, init_ef, wire_bytes
from repro.training.elastic import Action, StragglerMonitor

__all__ = ["AdamW", "SGD", "AdamWState", "warmup_cosine", "TrainLoopConfig",
           "make_train_step", "run_loop", "checkpoint", "compress",
           "init_ef", "wire_bytes", "Action", "StragglerMonitor"]
