"""Top-k gradient compression with error feedback (Deep Gradient
Compression-style) for bandwidth-constrained inter-pod links.

``compress`` keeps the largest-|g| fraction per leaf and accumulates the
residual into an error-feedback buffer that is replayed next step, keeping
the optimizer unbiased in expectation.  The sparsified gradient is returned
dense (zeros elsewhere) — on a real fabric the (indices, values) pairs are
what cross pods; ``wire_bytes`` reports that cost for the roofline log.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict


def init_ef(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _topk_mask(g: jax.Array, keep_frac: float) -> jax.Array:
    if g.size <= 64:                      # tiny leaves always go dense
        return jnp.ones_like(g, jnp.bool_)
    k = max(1, int(g.size * keep_frac))
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh)


def compress(grads, ef: EFState, keep_frac: float = 0.01
             ) -> tuple[dict, EFState]:
    """Returns (sparsified grads, updated error-feedback state)."""
    def per_leaf(g, r):
        acc = g.astype(jnp.float32) + r
        mask = _topk_mask(acc, keep_frac)
        sent = jnp.where(mask, acc, 0.0)
        return sent.astype(g.dtype), acc - sent

    pairs = jax.tree.map(per_leaf, grads, ef.residual)
    sent = jax.tree.map(lambda x: x[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda x: x[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return sent, EFState(residual=resid)


def wire_bytes(params, keep_frac: float) -> int:
    """Bytes a real sparse all-reduce would move per step (idx32 + fp16)."""
    total = 0
    for p in jax.tree.leaves(params):
        if p.size <= 64:
            total += p.size * 2
        else:
            total += int(p.size * keep_frac) * (4 + 2)
    return total
