"""Optimizers (pure-JAX pytree implementation, no external deps).

AdamW with fp32 master weights + moments (params may live in bf16), global
gradient-norm clipping, and warmup-cosine schedules.  The state layout is a
flat NamedTuple-of-pytrees so checkpointing and ZeRO sharding rules apply
uniformly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict
    master: dict          # fp32 copy of the params


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0

    def init(self, params) -> AdamWState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(f32, params),
            nu=jax.tree.map(f32, params),
            # copy=True: fp32 params must not alias the master weights
            # (param + opt-state donation would otherwise donate one
            # buffer twice)
            master=jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True),
                params),
        )

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state: AdamWState, params
               ) -> tuple[dict, AdamWState]:
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = global_norm(g32)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-12))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t
        lr = self._lr(step)

        def upd(g, m, v, w):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            w = w - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                          + self.weight_decay * w)
            return m, v, w

        flat = jax.tree.map(upd, g32, state.mu, state.nu, state.master)
        mu = jax.tree.map(lambda x: x[0], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda x: x[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        master = jax.tree.map(lambda x: x[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), master, params)
        return new_params, AdamWState(step=step, mu=mu, nu=nu, master=master)


@dataclass(frozen=True)
class SGD:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-2
    momentum: float = 0.9

    def init(self, params):
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params),
            nu={}, master=jax.tree.map(lambda p: p.astype(jnp.float32),
                                       params))

    def update(self, grads, state, params):
        lr = self.lr(state.step + 1) if callable(self.lr) else self.lr
        mu = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state.mu, grads)
        master = jax.tree.map(lambda w, m: w - lr * m, state.master, mu)
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master,
                                  params)
        return new_params, AdamWState(step=state.step + 1, mu=mu, nu={},
                                      master=master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        t = step.astype(jnp.float32)
        warm = t / max(warmup_steps, 1)
        frac = jnp.clip((t - warmup_steps) / max(total_steps - warmup_steps,
                                                 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak * jnp.minimum(warm, cos)
    return sched
