"""Straggler detection + elastic-restart policy.

On real fleets the failure modes are: a host dies (step hangs), a host slows
(step-time tail inflates), or a pod link degrades.  The monitor tracks
per-step wall times, flags stragglers by quantile ratio, and decides among
CONTINUE / CHECKPOINT_AND_SHRINK / ABORT.  The training launcher consults it
every step; on SHRINK it checkpoints (mesh-shape-agnostic, see
``checkpoint.py``) and re-launches with a smaller data axis — the sharding
rules are written against axis roles so no model code changes.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable

log = logging.getLogger(__name__)


class Action(Enum):
    CONTINUE = "continue"
    CHECKPOINT_AND_SHRINK = "checkpoint_and_shrink"
    ABORT = "abort"


@dataclass
class StragglerMonitor:
    window: int = 50
    straggler_ratio: float = 2.5       # p99/p50 step-time ratio threshold
    hang_timeout_s: float = 300.0
    consecutive_to_shrink: int = 3
    clock: Callable[[], float] = time.monotonic   # injectable for tests
    _times: list[float] = field(default_factory=list)
    _flags: int = 0
    _last_start: float | None = None

    def step_started(self) -> None:
        self._last_start = self.clock()

    def step_finished(self) -> Action:
        if self._last_start is None:
            # A finish with no matching start (caller skipped step_started,
            # or a double-finish) carries no timing signal; dropping the
            # sample beats crashing the step loop it is meant to protect.
            log.warning("step_finished() without step_started(); "
                        "sample dropped")
            return Action.CONTINUE
        dt = self.clock() - self._last_start
        self._last_start = None
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        return self._evaluate(dt)

    def hung(self) -> bool:
        return (self._last_start is not None and
                self.clock() - self._last_start > self.hang_timeout_s)

    def _evaluate(self, dt: float) -> Action:
        if len(self._times) < max(10, self.window // 5):
            return Action.CONTINUE
        xs = sorted(self._times)
        p50 = xs[len(xs) // 2]
        p99 = xs[min(len(xs) - 1, int(len(xs) * 0.99))]
        if dt > self.hang_timeout_s:
            return Action.ABORT
        # The *current* step counts as a straggler when it exceeds the
        # windowed median by the configured ratio.
        if p50 > 0 and dt > self.straggler_ratio * p50:
            self._flags += 1
            if self._flags >= self.consecutive_to_shrink:
                self._flags = 0
                return Action.CHECKPOINT_AND_SHRINK
        else:
            self._flags = 0
        return Action.CONTINUE

    def stats(self) -> dict:
        if not self._times:
            return {}
        xs = sorted(self._times)
        return {
            "n": len(xs),
            "p50_s": xs[len(xs) // 2],
            "p90_s": xs[min(len(xs) - 1, int(len(xs) * 0.9))],
            "p99_s": xs[min(len(xs) - 1, int(len(xs) * 0.99))],
            "max_s": xs[-1],
        }


def shrink_mesh_shape(shape: tuple[int, ...], lost_fraction: float = 0.5
                      ) -> tuple[int, ...]:
    """Halve the leading (data) axis — the elastic fallback layout.  Model
    sharding is untouched so checkpoints reshard without repartitioning the
    network."""
    lead = max(1, shape[0] // 2)
    return (lead,) + tuple(shape[1:])
