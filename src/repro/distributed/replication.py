"""r-way replication over the row-sharded CF arena.

The serving arena is row-sharded (``shard_row_slice`` — the same even
row split every CF arena spec uses, ``P(ax.all, None)``).  At fleet
scale a shard's host dying is routine; without replication the only
recovery PR 2 offered was rollback to the last snapshot, which *loses*
every onboard since it.  Landmark-style rebuilds (Lima et al.,
arXiv:1705.07051) trade accuracy for speed; replication instead keeps
``r`` byte-identical copies of every row slice, so recovery is **exact
and similarity-free**:

  * **placement** — replica j of shard s lives on node ``(s + j) % n``
    (chained declustering): any single node loss leaves every shard with
    at least one survivor for all ``r >= 2``;
  * **health** — per-replica state (HEALTHY / REBUILDING / DEAD) driven
    by the same invariant family as the serving layer's poison detector
    (``verify_rows``: live similarity lists finite + ascending, finite
    ratings/norms), swept per replica slice;
  * **failover reads / repair** — a poisoned primary row is re-read from
    the first healthy replica of its shard (``repair``): pure data
    movement, bit-exact, zero similarity recompute;
  * **re-replication** — a lost replica is rebuilt by copying rows from
    a surviving replica of the same shard (never from the primary, which
    may itself be the casualty), incrementally under a per-call row
    budget so it runs as background work between requests.

Everything here is host-side ``np`` data movement over slices defined by
``shard_row_slice``; no jitted kernel is ever invoked — the replica-kill
tests assert that by making every similarity kernel raise.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass
from enum import Enum

import numpy as np

from repro.distributed.sharding import shard_row_slice

log = logging.getLogger(__name__)

# The arena fields a replica mirrors, in checkpoint order.
FIELDS = ("ratings", "norms", "sim_vals", "sim_idx")


class ReplicaState(Enum):
    HEALTHY = "healthy"
    REBUILDING = "rebuilding"
    DEAD = "dead"


@dataclass(frozen=True)
class ReplicationConfig:
    n_shards: int = 4
    r: int = 2                     # replica factor (copies per shard)
    rebuild_rows: int = 0          # rows copied per step_rebuild call; 0 = all

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not 1 <= self.r <= self.n_shards:
            raise ValueError(
                f"replica factor r={self.r} outside [1, {self.n_shards}]")

    def owners(self, shard: int) -> tuple[int, ...]:
        """Nodes holding shard ``shard``, primary first (chained
        declustering)."""
        return tuple((shard + j) % self.n_shards for j in range(self.r))


class _Replica:
    """One (node, shard) copy: per-field row-slice arrays + health."""

    __slots__ = ("node", "shard", "state", "data", "progress")

    def __init__(self, node: int, shard: int):
        self.node = node
        self.shard = shard
        self.state = ReplicaState.HEALTHY
        self.data: dict[str, np.ndarray] = {}
        self.progress = 0              # rows copied so far while REBUILDING


def _row_ok(ratings: np.ndarray, norms: np.ndarray,
            sim_vals: np.ndarray) -> np.ndarray:
    """Per-row arena invariant (the ``verify_rows`` family contract):
    finite ratings and norms, finite ascending similarity lists."""
    fin_r = np.isfinite(ratings).all(axis=1)
    fin_n = np.isfinite(norms) & (norms >= 0)
    fin_s = np.isfinite(sim_vals).all(axis=1)
    asc = (np.diff(sim_vals, axis=1) >= 0).all(axis=1)
    return fin_r & fin_n & fin_s & asc


class ReplicatedArena:
    """r-way replicated mirror of a ``CFState``'s row-sharded fields.

    The primary arena stays the single jit-visible ``CFState``; this
    class owns the replica copies, their health, and the recovery data
    paths.  The serving layer keeps replicas in sync by calling
    ``apply_rows`` after each committed mutation and ``reset`` after a
    geometry change (rotation / rollback / restore).
    """

    def __init__(self, state, cfg: ReplicationConfig):
        self.cfg = cfg
        self.rebuilt_rows = 0          # re-replication row copies (lifetime)
        self.repaired_rows = 0         # primary rows healed from replicas
        self.dead_marks = 0            # replicas lost (kill + sweep)
        self._replicas: dict[tuple[int, int], _Replica] = {}
        for s in range(cfg.n_shards):
            for node in cfg.owners(s):
                self._replicas[(node, s)] = _Replica(node, s)
        self.reset(state)

    # -- geometry -----------------------------------------------------------

    def reset(self, state) -> None:
        """(Re)build every live replica from ``state`` — full
        re-replication after construction or an arena geometry change."""
        self.n_rows = int(state.capacity)
        if self.n_rows < self.cfg.n_shards:
            raise ValueError(
                f"arena of {self.n_rows} rows cannot spread over "
                f"{self.cfg.n_shards} shards")
        self.n_active = int(state.n_active)
        self._slices = [shard_row_slice(self.n_rows, self.cfg.n_shards, s)
                        for s in range(self.cfg.n_shards)]
        host = {f: np.asarray(getattr(state, f)) for f in FIELDS}
        for rep in self._replicas.values():
            if rep.state is ReplicaState.DEAD:
                continue
            sl = self._slices[rep.shard]
            rep.data = {f: host[f][sl].copy() for f in FIELDS}
            rep.state = ReplicaState.HEALTHY
            rep.progress = 0

    def shard_of(self, row: int) -> int:
        per = max(1, self.n_rows // self.cfg.n_shards)
        return min(row // per, self.cfg.n_shards - 1)

    def _live_for_write(self, rep: _Replica, local_row: int) -> bool:
        if rep.state is ReplicaState.HEALTHY:
            return True
        # A rebuilding replica takes writes only for rows already copied;
        # later rows pick the write up from the (already-written) source.
        return (rep.state is ReplicaState.REBUILDING
                and local_row < rep.progress)

    # -- write path ---------------------------------------------------------

    def apply_rows(self, rows, state) -> None:
        """Mirror the given primary rows (all fields) into every live
        replica — called after each committed onboard/add_rating."""
        self.n_active = int(state.n_active)
        for row in rows:
            row = int(row)
            s = self.shard_of(row)
            lo = self._slices[s].start
            vals = {f: np.asarray(getattr(state, f)[row]) for f in FIELDS}
            for node in self.cfg.owners(s):
                rep = self._replicas[(node, s)]
                if self._live_for_write(rep, row - lo):
                    for f in FIELDS:
                        rep.data[f][row - lo] = vals[f]

    # -- health -------------------------------------------------------------

    def kill_node(self, node: int) -> list[tuple[int, int]]:
        """Lose a node: every replica it stores is gone."""
        lost = []
        for (n, s), rep in self._replicas.items():
            if n == node and rep.state is not ReplicaState.DEAD:
                rep.state = ReplicaState.DEAD
                rep.data = {}
                rep.progress = 0
                self.dead_marks += 1
                lost.append((n, s))
        if lost:
            log.warning("node %d lost: %d replicas dead", node, len(lost))
        return lost

    def sweep(self) -> list[tuple[int, int]]:
        """Run the invariant sweep over every healthy replica's slice;
        poisoned replicas (bit-flips, partial loss) go DEAD.  Returns the
        newly dead (node, shard) pairs."""
        newly_dead = []
        for (node, s), rep in self._replicas.items():
            if rep.state is not ReplicaState.HEALTHY:
                continue
            sl = self._slices[s]
            live = min(max(self.n_active - sl.start, 0), sl.stop - sl.start)
            if live == 0:
                continue
            ok = _row_ok(rep.data["ratings"][:live],
                         rep.data["norms"][:live],
                         rep.data["sim_vals"][:live])
            if not ok.all():
                rep.state = ReplicaState.DEAD
                rep.data = {}
                self.dead_marks += 1
                newly_dead.append((node, s))
                log.warning("replica (node=%d, shard=%d) failed the "
                            "invariant sweep; marked dead", node, s)
        return newly_dead

    def redundancy(self) -> int:
        """Minimum healthy replica count over all shards."""
        return min(
            sum(self._replicas[(n, s)].state is ReplicaState.HEALTHY
                for n in self.cfg.owners(s))
            for s in range(self.cfg.n_shards))

    def degraded(self) -> bool:
        return self.redundancy() < self.cfg.r

    def replica_states(self) -> dict[tuple[int, int], str]:
        return {k: rep.state.value for k, rep in self._replicas.items()}

    # -- read failover / repair --------------------------------------------

    def read_row(self, field: str, row: int) -> np.ndarray | None:
        """Row ``row`` of ``field`` from the first healthy replica of its
        shard (failover read); None if every replica is down."""
        s = self.shard_of(row)
        local = row - self._slices[s].start
        for node in self.cfg.owners(s):
            rep = self._replicas[(node, s)]
            if rep.state is ReplicaState.HEALTHY or (
                    rep.state is ReplicaState.REBUILDING
                    and local < rep.progress):
                return rep.data[field][local]
        return None

    def bad_rows(self, state) -> np.ndarray:
        """Live primary rows violating the arena invariant."""
        n_act = int(state.n_active)
        if n_act == 0:
            return np.empty((0,), np.int64)
        ok = _row_ok(np.asarray(state.ratings[:n_act]),
                     np.asarray(state.norms[:n_act]),
                     np.asarray(state.sim_vals[:n_act]))
        return np.nonzero(~ok)[0]

    def repair(self, state):
        """Heal poisoned primary rows from healthy replicas.

        Returns ``(fixed_state, repaired_row_ids)``; ``fixed_state`` is
        None when some poisoned row has no surviving replica (the caller
        falls back to snapshot rollback).  Pure data movement."""
        import jax.numpy as jnp

        rows = self.bad_rows(state)
        if rows.size == 0:
            return state, rows
        host = {f: np.asarray(getattr(state, f)).copy() for f in FIELDS}
        for row in rows:
            for f in FIELDS:
                src = self.read_row(f, int(row))
                if src is None:
                    log.error("row %d unrecoverable: all replicas of "
                              "shard %d down", row, self.shard_of(int(row)))
                    return None, rows
                host[f][row] = src
        self.repaired_rows += int(rows.size)
        fixed = state._replace(
            **{f: jnp.asarray(host[f]) for f in FIELDS})
        return fixed, rows

    # -- re-replication -----------------------------------------------------

    def step_rebuild(self, budget_rows: int | None = None) -> int:
        """Advance background re-replication by up to ``budget_rows`` row
        copies (None/0 = the config's ``rebuild_rows``; 0 there = finish
        everything).  Copies come from a surviving replica of the same
        shard — never the primary.  Returns rows copied."""
        if budget_rows is None:
            budget_rows = self.cfg.rebuild_rows
        remaining = budget_rows if budget_rows > 0 else None
        copied = 0
        for (node, s), rep in sorted(self._replicas.items()):
            if rep.state is ReplicaState.DEAD:
                src = self._source_for(s, exclude=node)
                if src is None:
                    continue           # no survivor yet; stay dead
                rep.state = ReplicaState.REBUILDING
                rep.progress = 0
                rep.data = {f: np.empty_like(src.data[f]) for f in FIELDS}
            if rep.state is not ReplicaState.REBUILDING:
                continue
            src = self._source_for(s, exclude=node)
            if src is None:
                continue
            n_rows = self._slices[s].stop - self._slices[s].start
            take = n_rows - rep.progress
            if remaining is not None:
                take = min(take, remaining)
            if take > 0:
                lo, hi = rep.progress, rep.progress + take
                for f in FIELDS:
                    rep.data[f][lo:hi] = src.data[f][lo:hi]
                rep.progress += take
                copied += take
                if remaining is not None:
                    remaining -= take
            if rep.progress >= n_rows:
                rep.state = ReplicaState.HEALTHY
                rep.progress = 0
            if remaining == 0:
                break
        self.rebuilt_rows += copied
        return copied

    def _source_for(self, shard: int, exclude: int) -> _Replica | None:
        for node in self.cfg.owners(shard):
            if node == exclude:
                continue
            rep = self._replicas[(node, shard)]
            if rep.state is ReplicaState.HEALTHY:
                return rep
        return None

    def stats(self) -> dict:
        states = list(self._replicas.values())
        return {
            "n_shards": self.cfg.n_shards,
            "r": self.cfg.r,
            "redundancy": self.redundancy(),
            "healthy": sum(r.state is ReplicaState.HEALTHY for r in states),
            "rebuilding": sum(r.state is ReplicaState.REBUILDING
                              for r in states),
            "dead": sum(r.state is ReplicaState.DEAD for r in states),
            "rebuilt_rows": self.rebuilt_rows,
            "repaired_rows": self.repaired_rows,
        }
