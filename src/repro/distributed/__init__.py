from repro.distributed.replication import (ReplicaState, ReplicatedArena,
                                           ReplicationConfig)
from repro.distributed.sharding import (MeshAxes, cf_shardings,
                                        gnn_shardings, lm_shardings,
                                        mesh_axes, named, recsys_shardings,
                                        zero_extend)

__all__ = ["MeshAxes", "cf_shardings", "gnn_shardings", "lm_shardings",
           "mesh_axes", "named", "recsys_shardings", "zero_extend",
           "ReplicaState", "ReplicatedArena", "ReplicationConfig"]
