"""Per-family sharding rules (DESIGN.md §5).

Every rule is written against axis *roles*, not literal mesh shapes:
``dp`` = the data-parallel axes (('pod','data') on the multi-pod mesh,
('data',) on one pod), ``mp`` = the model/tensor axis.  ``all`` = every
axis (used for row-sharding giant embedding tables / similarity lists).

Functions return pytrees of ``PartitionSpec`` matching the corresponding
param/input pytrees; ``launch/dryrun.py`` wraps them into NamedShardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CFConfig, GNNConfig, LMConfig, RecsysConfig
from repro.models.transformer import LMShardingHooks, is_global_layer


@dataclass(frozen=True)
class MeshAxes:
    dp: tuple[str, ...]          # data-parallel axes
    mp: str                      # model/tensor axis
    sizes: dict[str, int]

    @property
    def all(self) -> tuple[str, ...]:
        return self.dp + (self.mp,)

    @property
    def mp_size(self) -> int:
        return self.sizes[self.mp]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp:
            n *= self.sizes[a]
        return n


def mesh_axes(mesh: jax.sharding.Mesh) -> MeshAxes:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    assert names[-1] == "model", names
    return MeshAxes(dp=tuple(names[:-1]), mp="model", sizes=sizes)


def named(mesh: jax.sharding.Mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

FSDP_MIN_LEAF = 1 << 22       # leaves >= 4M elements also shard over dp


def _fsdp_axes(ax: MeshAxes, dim_size: int) -> tuple | str:
    """Extend the model axis with the largest dp-axis prefix that divides
    ``dim_size`` — maxtext-style ('tensor','fsdp') weight sharding.  The dp
    axes land on a weight dim that is NEVER a contraction dim of its
    matmul, so GSPMD resolves the mismatch with a weight-sized all-gather
    (true FSDP) rather than activation-sized partial-sum psums."""
    chosen: list = [ax.mp]
    prod = ax.mp_size
    for a in ax.dp[::-1]:                    # minor-most dp axis first
        if dim_size % (prod * ax.sizes[a]) == 0:
            chosen.append(a)
            prod *= ax.sizes[a]
    return tuple(chosen) if len(chosen) > 1 else ax.mp


def lm_param_specs(cfg: LMConfig, ax: MeshAxes,
                   decode: bool = False) -> dict:
    """Megatron TP over ``model`` + FSDP: each weight's mp-sharded
    *output* dim extends over the dp axes where divisible, so a 100B-param
    MoE stores ~0.5GB/chip instead of 13.4GB at the cost of weight-sized
    per-layer all-gathers (visible in the collective roofline term, exactly
    as on a real FSDP fleet).

    ``decode=True`` switches to weight-stationary sharding: the dp axes go
    on *contraction* dims instead, trading the (unrolled-decode-hoisted)
    weight all-gathers for activation-sized partial-sum psums — negligible
    at decode shapes (measured: llama4 decode temp 58GB -> fits)."""
    mp = ax.mp
    shard_kv = cfg.n_kv_heads % ax.mp_size == 0
    if decode:
        def fa(n):                           # weights stay sharded in place
            return _fsdp_axes(ax, n)
        # contraction-dim dp sharding applied post-hoc below
    else:
        fa = lambda n: _fsdp_axes(ax, n)    # noqa: E731
    layers: dict[str, P] = {
        "attn_norm": P(None, None),
        "mlp_norm": P(None, None),
        "wq": P(None, None, fa(cfg.q_dim)),
        "wk": P(None, None, fa(cfg.kv_dim)) if shard_kv
        else P(None, None, None),
        "wv": P(None, None, fa(cfg.kv_dim)) if shard_kv
        else P(None, None, None),
        "wo": P(None, fa(cfg.q_dim), None),
    }
    if cfg.moe is not None:
        m = cfg.moe
        gf = 2 if cfg.act in ("swiglu", "geglu") else 1
        shard_e = m.n_experts % ax.mp_size == 0
        if shard_e:
            # Experts over mp; the output dim (f for w_in, d for w_out)
            # takes the dp/FSDP axes.
            dp_f = zero_extend(P(None, mp, None, None),
                               (1, m.n_experts, cfg.d_model,
                                gf * m.d_ff_expert), ax, start=3)
            dp_d = zero_extend(P(None, mp, None, None),
                               (1, m.n_experts, m.d_ff_expert,
                                cfg.d_model), ax, start=3)
            espec_in, espec_out = dp_f, dp_d
        else:
            espec_in = P(None, None, None, fa(gf * m.d_ff_expert))
            espec_out = P(None, None, fa(m.d_ff_expert), None)
        layers.update({
            "router": P(None, None, None),
            "w_in_e": espec_in,
            "w_out_e": espec_out,
        })
        if m.n_shared:
            layers["w_in_sh"] = P(None, None,
                                  fa(gf * m.n_shared * m.d_ff_expert))
            layers["w_out_sh"] = P(None, fa(m.n_shared * m.d_ff_expert),
                                   None)
    else:
        gf = 2 if cfg.act in ("swiglu", "geglu") else 1
        layers["w_in"] = P(None, None, fa(gf * cfg.d_ff))
        layers["w_out"] = P(None, fa(cfg.d_ff), None)
    specs = {
        "embed": P(mp, None),
        "layers": layers,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, mp)
    if decode:
        # Weight-stationary: replace (mp, dp...) output-dim extensions with
        # dp on the first free dim >= 1 (contraction) — no gathers at all.
        import repro.models.transformer as lm_mod
        structs = lm_mod.param_structs(cfg)

        def stationary(spec, struct):
            # strip dp axes (keep mp / None), then re-extend on a free dim
            def strip(p):
                if isinstance(p, tuple):
                    kept = [a for a in p if a not in ax.dp]
                    return kept[0] if len(kept) == 1 else (
                        tuple(kept) if kept else None)
                return None if p in ax.dp else p
            base = P(*[strip(p) for p in tuple(spec)])
            if struct.size >= FSDP_MIN_LEAF:
                return zero_extend(base, struct.shape, ax, start=1)
            return base

        specs["layers"] = jax.tree.map(
            stationary, specs["layers"], structs["layers"],
            is_leaf=lambda x: isinstance(x, P))
    return specs


def lm_hooks(cfg: LMConfig, ax: MeshAxes) -> LMShardingHooks:
    seq = ax.mp if cfg.seq_shard else None
    moe_tok = moe_exp = None
    if cfg.moe is not None:
        moe_tok = P(ax.dp, None, None)
        moe_exp = (P(ax.dp, ax.mp, None, None)
                   if cfg.moe.n_experts % ax.mp_size == 0 else None)
    return LMShardingHooks(acts=P(ax.dp, seq, None),
                           logits=P(ax.dp, None, ax.mp),
                           moe_tokens=moe_tok, moe_experts=moe_exp)


def lm_batch_specs(ax: MeshAxes) -> dict:
    return {"tokens": P(ax.dp, None)}


def lm_cache_specs(cfg: LMConfig, ax: MeshAxes, batch: int,
                   seq_len: int) -> dict:
    """Decode cache: batch over dp when it divides; heads over mp when they
    divide; otherwise (MQA / small GQA / batch=1 long-context) the
    sequence axis takes the leftover axes (flash-decoding split — GSPMD
    partitions the contraction + softmax across the cache shards)."""
    mp = ax.mp
    heads_ok = cfg.n_kv_heads % ax.mp_size == 0
    b_ok = batch % ax.dp_size == 0

    def seq_axes(length: int, avail: tuple):
        """Largest prefix of ``avail`` whose product divides ``length``."""
        chosen: list = []
        prod = 1
        for a in avail:
            if length % (prod * ax.sizes[a]) == 0:
                chosen.append(a)
                prod *= ax.sizes[a]
        return tuple(chosen) if chosen else None

    def cache_spec(length: int) -> P:
        if b_ok and heads_ok:
            return P(None, ax.dp, None, mp, None)
        if b_ok:
            return P(None, ax.dp, seq_axes(length, (mp,)), None, None)
        if heads_ok:
            return P(None, None, seq_axes(length, ax.dp), mp, None)
        return P(None, None, seq_axes(length, ax.dp + (mp,)), None, None)

    specs = {}
    has_global = cfg.window is None or cfg.global_every is not None
    if has_global:
        full = cache_spec(seq_len)
        specs["kg"] = full
        specs["vg"] = full
    if cfg.window is not None:
        ring = cache_spec(cfg.window)
        specs.update(kl=ring, vl=ring, ring_pos=P(None))
    return specs


def lm_shardings(cfg: LMConfig, ax: MeshAxes, kind: str, batch: int,
                 seq_len: int) -> dict:
    params = lm_param_specs(cfg, ax, decode=(kind == "decode"))
    hooks = lm_hooks(cfg, ax)
    # Expert parallelism (shard_map all-to-all) whenever experts divide the
    # model axis and activations are sharded (train/prefill cells).
    if (cfg.moe is not None and cfg.moe.n_experts % ax.mp_size == 0
            and kind in ("train", "prefill")):
        from repro.models.moe_ep import MoEEPInfo
        win = params["layers"]["w_in_e"]
        wout = params["layers"]["w_out_e"]
        hooks = hooks._replace(moe_ep=MoEEPInfo(
            dp=ax.dp, mp=ax.mp, mp_size=ax.mp_size,
            win_spec=P(*tuple(win)[1:]),
            wout_spec=P(*tuple(wout)[1:]),
            acts_spec=hooks.acts,
        ))
    out: dict[str, Any] = {
        "params": params,
        "hooks": hooks,
    }
    b = ax.dp if batch % ax.dp_size == 0 else None
    if kind == "train":
        out["inputs"] = {"tokens": P(b, None)}
    elif kind == "prefill":
        out["inputs"] = {"tokens": P(b, None)}
        out["cache"] = lm_cache_specs(cfg, ax, batch, seq_len)
    elif kind == "decode":
        out["inputs"] = {
            "cache": lm_cache_specs(cfg, ax, batch, seq_len),
            "tokens": P(b, None),
            "pos": P(),
        }
        out["logits"] = P(b, ax.mp)
    return out


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def gnn_shardings(cfg: GNNConfig, ax: MeshAxes, kind: str) -> dict:
    params = jax.tree.map(lambda _: P(), {"l1": {"W": 0, "a_src": 0,
                                                 "a_dst": 0},
                                          "l2": {"W": 0, "a_src": 0,
                                                 "a_dst": 0}})
    if kind == "train_full":
        inputs = {
            "feats": P(ax.dp, None),
            "edge_src": P(ax.all),
            "edge_dst": P(ax.all),
            "labels": P(ax.dp),
            "mask": P(ax.dp),
        }
    elif kind == "train_sampled":
        inputs = {
            "feats": P(ax.all, None),     # sharded feature store
            "roots": P(ax.dp),
            "nbr1": P(ax.dp, None),
            "nbr2": P(ax.dp, None),
            "labels": P(ax.dp),
        }
    else:                                  # train_batched
        inputs = {
            "feats": P(ax.dp, None, None),
            "edge_src": P(ax.dp, None),
            "edge_dst": P(ax.dp, None),
            "labels": P(ax.dp),
        }
    return {"params": params, "inputs": inputs}


# ---------------------------------------------------------------------------
# Recsys
# ---------------------------------------------------------------------------

def _recsys_param_specs(params_struct, ax: MeshAxes) -> Any:
    """Tables (any leaf with >= 2**16 rows) shard rows over every axis;
    small dense params replicate."""
    def rule(path, leaf):
        if leaf.ndim >= 1 and leaf.shape[0] >= (1 << 16):
            return P(ax.all, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(rule, params_struct)


def recsys_shardings(cfg: RecsysConfig, ax: MeshAxes, kind: str,
                     params_struct) -> dict:
    params = _recsys_param_specs(params_struct, ax)
    inputs: dict[str, P] = {}
    if cfg.variant == "two_tower" and kind == "retrieval":
        # 1M candidates shard over dp only (10^6 is not 512-divisible).
        inputs = {"user_id": P(), "user_fields": P(None, None),
                  "cand_ids": P(ax.dp), "cand_fields": P(ax.dp, None)}
    else:
        key_rank = {"sparse_idx": 2, "dense": 2, "multi_idx": 2,
                    "multi_mask": 2, "hist": 2, "target": 1, "label": 1,
                    "user_id": 1, "user_fields": 2, "item_id": 1,
                    "item_fields": 2}
        for k, r in key_rank.items():
            inputs[k] = P(ax.dp, *([None] * (r - 1)))
    return {"params": params, "inputs": inputs}


# ---------------------------------------------------------------------------
# CF (the paper)
# ---------------------------------------------------------------------------

def shard_row_slice(n_rows: int, n_shards: int, shard: int) -> slice:
    """Row range owned by ``shard`` under the even row-sharding every CF
    arena spec uses (``P(ax.all, None)``).  The serving fault harness keys
    on this to simulate shard loss: the rows a dead shard would stop
    serving are exactly this slice."""
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} outside [0, {n_shards})")
    per = n_rows // n_shards
    lo = shard * per
    hi = n_rows if shard == n_shards - 1 else lo + per
    return slice(lo, hi)


def cf_shardings(cfg: CFConfig, ax: MeshAxes, kind: str) -> dict:
    rows_all = P(ax.all, None)
    if kind == "build":
        return {
            "inputs": {"R": P(ax.dp, None)},
            "block": P(ax.dp, ax.mp),
            "rows": rows_all,
            "out": (rows_all, rows_all),
        }
    # onboard
    from repro.core.types import CFState
    return {
        "inputs": {
            "state": CFState(
                ratings=rows_all,
                norms=P(ax.all),
                sim_vals=rows_all,
                sim_idx=rows_all,
                n_active=P(),
            ),
            "R_new": P(None, None),
            "probes": P(None, None),
        },
    }


# ---------------------------------------------------------------------------
# Optimizer-state sharding (ZeRO-1-style extension)
# ---------------------------------------------------------------------------

def zero_extend(spec: P, shape: tuple[int, ...], ax: MeshAxes,
                start: int = 0) -> P:
    """Add dp sharding to the first unsharded, evenly-divisible axis (>=
    ``start``) of a leaf so Adam moments/master weights/FSDP params spread
    over the data axes instead of replicating.  No-op if any dp axis is
    already used (e.g. embedding tables row-sharded over every axis)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used: set[str] = set()
    for p in parts:
        if p is None:
            continue
        for a in (p if isinstance(p, tuple) else (p,)):
            used.add(a)
    if used & set(ax.dp):
        return P(*parts)
    dp_n = ax.dp_size
    for i, (p, s) in enumerate(zip(parts, shape)):
        if i < start:
            continue
        if p is None and s % dp_n == 0 and s >= dp_n:
            parts[i] = ax.dp
            return P(*parts)
    return P(*parts)
