"""Deterministic LM token pipeline.

Synthetic corpus with bigram structure (so a ~100M-param model visibly
learns), generated stateless-per-step from (seed, step) — restart at step k
trivially replays the exact stream, which is what the checkpoint/resume
integration test asserts.
"""
from __future__ import annotations

import numpy as np


def _bigram_table(seed: int, vocab: int, branch: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, branch), dtype=np.int32)


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self._table = _bigram_table(seed, vocab)

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng([self.seed, step])
        toks = np.empty((self.batch, self.seq), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        branch = self._table.shape[1]
        choices = rng.integers(0, branch, size=(self.batch, self.seq))
        for t in range(1, self.seq):
            toks[:, t] = self._table[toks[:, t - 1], choices[:, t]]
        return {"tokens": toks}

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "next_step": step}
