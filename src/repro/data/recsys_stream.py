"""Synthetic CTR / retrieval batch streams.

Per-field Zipf-distributed ids (hot-row skew like production traffic),
labels drawn from a hidden sparse-linear teacher so AUC visibly improves,
and stateless (seed, step) generation for exact restart replay.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.recsys import MULTI_HOT, _N_ITEM_FIELDS, _N_USER_FIELDS


def _zipf_ids(rng, vocab: int, size, a: float = 1.3) -> np.ndarray:
    raw = rng.zipf(a, size=size)
    return ((raw - 1) % vocab).astype(np.int32)


class CTRStream:
    def __init__(self, cfg: RecsysConfig, batch: int, seed: int = 0):
        self.cfg, self.batch, self.seed = cfg, batch, seed
        rng = np.random.default_rng(seed)
        self._field_w = rng.normal(0, 1.0, len(cfg.field_vocab_sizes))
        self._dense_w = rng.normal(0, 0.5, cfg.n_dense or 0)

    def __call__(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng([self.seed, step])
        B = self.batch
        idx = np.stack([_zipf_ids(rng, v, B)
                        for v in cfg.field_vocab_sizes], axis=1)
        batch: dict = {"sparse_idx": idx}
        score = (self._field_w[None, :] * ((idx % 7) - 3) / 3.0).sum(1)
        if cfg.n_dense:
            dense = rng.normal(0, 1, (B, cfg.n_dense)).astype(np.float32)
            batch["dense"] = dense
            score = score + dense @ self._dense_w
        if cfg.variant == "xdeepfm":
            batch["multi_idx"] = _zipf_ids(
                rng, cfg.field_vocab_sizes[0], (B, MULTI_HOT))
            batch["multi_mask"] = rng.random((B, MULTI_HOT)) < 0.6
        if cfg.variant == "bst":
            batch["hist"] = _zipf_ids(rng, cfg.item_vocab, (B, cfg.seq_len))
            batch["target"] = _zipf_ids(rng, cfg.item_vocab, B)
            score = score + ((batch["target"] % 11) - 5) / 5.0
        p = 1 / (1 + np.exp(-(score - score.mean())))
        batch["label"] = (rng.random(B) < p).astype(np.float32)
        return batch


class TwoTowerStream:
    def __init__(self, cfg: RecsysConfig, batch: int, seed: int = 0):
        self.cfg, self.batch, self.seed = cfg, batch, seed

    def __call__(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng([self.seed, step])
        B = self.batch
        uf = np.stack([_zipf_ids(rng, v, B) for v in
                       cfg.field_vocab_sizes[:_N_USER_FIELDS]], axis=1)
        itf = np.stack([_zipf_ids(rng, v, B) for v in
                        cfg.field_vocab_sizes[_N_USER_FIELDS:
                                              _N_USER_FIELDS +
                                              _N_ITEM_FIELDS]], axis=1)
        return {
            "user_id": _zipf_ids(rng, cfg.user_vocab, B),
            "user_fields": uf,
            "item_id": _zipf_ids(rng, cfg.item_vocab, B),
            "item_fields": itf,
            "label": np.ones(B, np.float32),
        }
