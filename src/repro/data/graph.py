"""Graph data: generators + a real neighbour sampler.

``NeighborSampler`` implements GraphSAGE-style fixed-fanout sampling from a
CSR adjacency (uniform with replacement, self-loop fallback for isolated
nodes) — the ``minibatch_lg`` cell's host-side companion.  Generators
produce power-law graphs at Cora / Reddit / ogbn-products scales plus
batched molecule graphs.
"""
from __future__ import annotations

import numpy as np


def random_graph(seed: int, n_nodes: int, n_edges: int, power: float = 1.2,
                 add_self_loops: bool = True
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Power-law (src, dst) int32 edge lists."""
    rng = np.random.default_rng(seed)
    p = np.arange(1, n_nodes + 1, dtype=np.float64) ** -power
    p /= p.sum()
    src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    if add_self_loops:
        loops = np.arange(n_nodes, dtype=np.int32)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
    return src, dst


def cora_like(seed: int = 0) -> dict:
    """2708 nodes, 10556 edges, 1433 binary features, 7 classes."""
    rng = np.random.default_rng(seed)
    n, d, c = 2708, 1433, 7
    src, dst = random_graph(seed, n, 10_556)
    labels = rng.integers(0, c, n).astype(np.int32)
    # Class-correlated sparse binary features (so GAT can learn).
    proto = rng.random((c, d)) < 0.015
    noise = rng.random((n, d)) < 0.005
    feats = (proto[labels] | noise).astype(np.float32)
    mask = np.zeros(n, bool)
    mask[rng.choice(n, 140, replace=False)] = True      # 20/class train split
    return {"feats": feats, "edge_src": src, "edge_dst": dst,
            "labels": labels, "mask": mask}


def molecule_batch(seed: int, batch: int, n_nodes: int = 30,
                   n_edges: int = 64, d_feat: int = 16,
                   n_classes: int = 2) -> dict:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
    loops = np.broadcast_to(np.arange(n_nodes, dtype=np.int32),
                            (batch, n_nodes))
    src = np.concatenate([src, loops], axis=1)
    dst = np.concatenate([dst, loops], axis=1)
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    feats = rng.normal(size=(batch, n_nodes, d_feat)).astype(np.float32)
    feats += labels[:, None, None] * 0.3
    return {"feats": feats, "edge_src": src, "edge_dst": dst,
            "labels": labels}


class CSR:
    def __init__(self, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        order = np.argsort(src, kind="stable")
        self.col = dst[order]
        counts = np.bincount(src, minlength=n_nodes)
        self.indptr = np.concatenate([[0], np.cumsum(counts)]).astype(
            np.int64)
        self.n_nodes = n_nodes


class NeighborSampler:
    """Fixed-fanout uniform neighbour sampling (with replacement; isolated
    nodes fall back to self-loops) producing the padded index arrays the
    ``train_sampled`` model path consumes."""

    def __init__(self, csr: CSR, fanouts: tuple[int, ...], seed: int = 0):
        self.csr, self.fanouts, self.seed = csr, fanouts, seed

    def _sample(self, rng, nodes: np.ndarray, fanout: int) -> np.ndarray:
        lo = self.csr.indptr[nodes]
        hi = self.csr.indptr[nodes + 1]
        deg = (hi - lo)
        r = rng.integers(0, np.maximum(deg, 1)[:, None],
                         size=(nodes.size, fanout))
        idx = np.minimum(lo[:, None] + r, len(self.csr.col) - 1)
        nbrs = self.csr.col[idx].astype(np.int32)
        return np.where(deg[:, None] > 0, nbrs, nodes[:, None].astype(
            np.int32))

    def __call__(self, step: int, roots: np.ndarray) -> dict:
        """2-hop block: roots (B,) -> nbr1 (B, f1), nbr2 (B(1+f1), f2)."""
        rng = np.random.default_rng([self.seed, step])
        f1, f2 = self.fanouts[0], self.fanouts[1]
        nbr1 = self._sample(rng, roots, f1)              # (B, f1)
        frontier = np.concatenate([roots[:, None], nbr1], axis=1).reshape(-1)
        nbr2 = self._sample(rng, frontier, f2)           # (B(1+f1), f2)
        return {"roots": roots.astype(np.int32), "nbr1": nbr1, "nbr2": nbr2}
