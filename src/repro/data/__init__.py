from repro.data.synthetic import (douban_film, movielens_100k, plant_twins,
                                  synth_ratings)
from repro.data.tokens import TokenPipeline
from repro.data.graph import (CSR, NeighborSampler, cora_like,
                              molecule_batch, random_graph)
from repro.data.recsys_stream import CTRStream, TwoTowerStream

__all__ = ["douban_film", "movielens_100k", "plant_twins", "synth_ratings",
           "TokenPipeline", "CSR", "NeighborSampler", "cora_like",
           "molecule_batch", "random_graph", "CTRStream", "TwoTowerStream"]
