"""Statistically faithful synthetic rating datasets.

The paper's datasets (MovieLens-100k: 943x1682, 100k ratings, >=20/user;
Douban film: 129,490x58,541, 16.8M ratings) are unavailable offline, so the
pipeline synthesises matrices with the published shapes and the properties
that matter to the algorithm's behaviour:

  * integral 1-5 stars with per-user mean bias + per-item quality bias
    (gives the Gaussian-ish similarity-value distribution the paper's
    Sec 3.2 analysis assumes — validated empirically in the benchmarks);
  * power-law item popularity;
  * per-user rating-count floor (MovieLens guarantees >= 20).

``movielens_100k``/``douban_film`` accept the real files when present
(``u.data`` tab format) and fall back to synthesis otherwise.
"""
from __future__ import annotations

import os

import numpy as np


def synth_ratings(seed: int, n_users: int, n_items: int, n_ratings: int,
                  min_per_user: int = 20, alpha: float = 0.8
                  ) -> np.ndarray:
    """Dense (n_users, n_items) int8 rating matrix, 0 = unrated."""
    rng = np.random.default_rng(seed)
    R = np.zeros((n_users, n_items), np.int8)

    # Power-law item popularity.
    pop = (np.arange(1, n_items + 1) ** -alpha)
    pop /= pop.sum()

    user_bias = rng.normal(0.0, 0.6, n_users)
    item_bias = rng.normal(0.0, 0.5, n_items)

    # Guarantee the per-user floor, then spread the remainder by popularity.
    base = min(min_per_user, max(1, n_ratings // n_users))
    for u in range(n_users):
        items = rng.choice(n_items, size=base, replace=False, p=pop)
        vals = np.clip(np.rint(3.5 + user_bias[u] + item_bias[items]
                               + rng.normal(0, 0.7, base)), 1, 5)
        R[u, items] = vals.astype(np.int8)
    # Top up to the requested count; popularity sampling collides, so loop
    # (bounded) until the deficit closes.
    for _ in range(12):
        deficit = n_ratings - int((R != 0).sum())
        if deficit <= 0:
            break
        us = rng.integers(0, n_users, deficit)
        its = rng.choice(n_items, size=deficit, p=pop)
        vals = np.clip(np.rint(3.5 + user_bias[us] + item_bias[its]
                               + rng.normal(0, 0.7, deficit)), 1, 5)
        R[us, its] = vals.astype(np.int8)
    return R


def movielens_100k(seed: int = 0, path: str | None = None) -> np.ndarray:
    """943 x 1682, 100k ratings (real ``u.data`` if available)."""
    path = path or os.environ.get("ML100K_PATH", "")
    if path and os.path.exists(path):
        R = np.zeros((943, 1682), np.int8)
        data = np.loadtxt(path, dtype=np.int64)
        R[data[:, 0] - 1, data[:, 1] - 1] = data[:, 2].astype(np.int8)
        return R
    return synth_ratings(seed, 943, 1682, 100_000, min_per_user=20)


def douban_film(seed: int = 0, n_users: int = 129_490,
                n_items: int = 58_541, subsample: float = 1.0) -> np.ndarray:
    """Douban-film-scale matrix; ``subsample`` < 1 scales both axes down
    (keeping density) for runs that must fit CPU memory/time."""
    nu = max(64, int(n_users * subsample))
    ni = max(64, int(n_items * subsample))
    nr = int(16_830_839 * (nu / n_users) * (ni / n_items))
    return synth_ratings(seed + 1, nu, ni, max(nr, nu * 5), min_per_user=5)


def plant_twins(R: np.ndarray, k: int, source_user: int | None = None,
                seed: int = 0) -> np.ndarray:
    """The paper's special case / kNN attack: k new users with an identical
    rating list.  Returns the (k, m) new-user block (a copy of an existing
    user's row, or a fresh profile with >= 8 ratings when source is None —
    Calandrino et al.'s attack floor)."""
    rng = np.random.default_rng(seed)
    if source_user is None:
        m = R.shape[1]
        row = np.zeros((m,), R.dtype)
        items = rng.choice(m, size=max(8, int(0.002 * m)), replace=False)
        row[items] = rng.integers(1, 6, items.size).astype(R.dtype)
    else:
        row = R[source_user].copy()
    return np.tile(row, (k, 1))
