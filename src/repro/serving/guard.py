"""Request validation, quarantine, and retry policy for the CF serving path.

Every request the server would hand to a jitted kernel passes through here
first.  A malformed payload (NaN/Inf ratings, wrong shape or dtype,
out-of-range values, bogus user/item ids) must never reach the compiled
program: a single NaN written into the similarity arena silently poisons
every downstream ``argsort``/``top_k``, and a wrong shape either recompiles
the kernel for a garbage signature or raises mid-update, leaving the state
half-written.  Rejected requests are *quarantined* — a bounded record of
what arrived and why it was refused, cheap enough to keep on the serving
hot path — and the caller gets a structured refusal instead of an
exception.

``call_with_retry`` is the transient-failure wrapper around the jitted
onboard call: exponential backoff with an overall deadline, with the sleep
and clock injectable so the fault-injection tests run in virtual time.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

# Rejection reasons (stable strings — they key quarantine counters).
R_DTYPE = "dtype"
R_SHAPE = "shape"
R_NON_FINITE = "non_finite"
R_RANGE = "range"
R_EMPTY = "empty"
R_USER_ID = "user_id"
R_ITEM_ID = "item_id"
R_ERROR = "error"          # the jitted call itself failed after retries


def _summarize(payload: Any) -> dict:
    """Small, jit-free description of a rejected payload (never the payload
    itself — quarantined data is recorded, not retained or re-fed)."""
    try:
        arr = np.asarray(payload)
        return {"shape": tuple(arr.shape), "dtype": str(arr.dtype)}
    except Exception:
        return {"type": type(payload).__name__}


@dataclass(frozen=True)
class Rejection:
    kind: str                  # which entrypoint refused ("onboard", ...)
    reason: str                # one of the R_* strings above
    detail: str = ""
    payload: dict = field(default_factory=dict)


@dataclass
class Quarantine:
    """Bounded record of refused requests + per-reason counters."""

    capacity: int = 256
    records: deque = field(init=False)
    counts: dict = field(default_factory=dict)
    total: int = 0

    def __post_init__(self) -> None:
        self.records = deque(maxlen=self.capacity)

    def record(self, kind: str, reason: str, payload: Any = None,
               detail: str = "") -> Rejection:
        rej = Rejection(kind=kind, reason=reason, detail=detail,
                        payload=_summarize(payload))
        self.records.append(rej)
        self.counts[reason] = self.counts.get(reason, 0) + 1
        self.total += 1
        return rej

    def summary(self) -> dict:
        return {"total": self.total, "by_reason": dict(self.counts),
                "held": len(self.records)}


# ---------------------------------------------------------------------------
# Validators — each returns a rejection reason or None (accepted).
# ---------------------------------------------------------------------------

def validate_ratings_vector(r: Any, *, n_items: int,
                            rating_range: tuple[float, float]) -> str | None:
    """One user's dense rating vector: (n_items,) numeric, finite, every
    non-zero value inside ``rating_range`` (0 = unrated), not all-zero."""
    try:
        arr = np.asarray(r)
    except Exception:
        return R_DTYPE
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.number):
        return R_DTYPE
    if arr.ndim != 1 or arr.shape[0] != n_items:
        return R_SHAPE
    arr = arr.astype(np.float64, copy=False)
    if not np.all(np.isfinite(arr)):
        return R_NON_FINITE
    lo, hi = rating_range
    rated = arr != 0
    if not rated.any():
        return R_EMPTY                  # zero-norm row: cosine undefined
    if np.any(rated & ((arr < lo) | (arr > hi))):
        return R_RANGE
    return None


def validate_rating_value(v: Any,
                          rating_range: tuple[float, float]) -> str | None:
    """A single rating: finite scalar, 0 (removal) or inside the range."""
    try:
        x = float(v)
    except (TypeError, ValueError):
        return R_DTYPE
    if not np.isfinite(x):
        return R_NON_FINITE
    lo, hi = rating_range
    if x != 0 and not (lo <= x <= hi):
        return R_RANGE
    return None


def validate_user_id(user: Any, n_active: int) -> str | None:
    try:
        u = int(user)
    except (TypeError, ValueError):
        return R_USER_ID
    if not 0 <= u < n_active:
        return R_USER_ID
    return None


def validate_item_id(item: Any, n_items: int) -> str | None:
    try:
        i = int(item)
    except (TypeError, ValueError):
        return R_ITEM_ID
    if not 0 <= i < n_items:
        return R_ITEM_ID
    return None


# ---------------------------------------------------------------------------
# Retry with exponential backoff + deadline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    base_delay_s: float = 0.01
    deadline_s: float = 5.0
    backoff: float = 2.0
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic
    # Called with the upcoming delay right before each backoff sleep — the
    # server uses it to drain background maintenance (rotation chunks)
    # during time it would otherwise spend blocked.
    on_wait: Callable[[float], None] | None = None


def call_with_retry(fn: Callable[[], Any],
                    policy: RetryPolicy) -> tuple[Any, int]:
    """Run ``fn`` with exponential backoff; returns (result, n_retries).

    Re-raises the last exception once attempts are exhausted or the next
    backoff would blow the deadline — the *caller* (the server) converts
    that into a quarantined structured failure; this helper stays honest
    about whether the call ever succeeded.
    """
    start = policy.clock()
    delay = policy.base_delay_s
    last: BaseException | None = None
    for attempt in range(policy.max_attempts):
        try:
            return fn(), attempt
        except Exception as e:            # noqa: BLE001 — wrapped, re-raised
            last = e
            elapsed = policy.clock() - start
            if (attempt + 1 >= policy.max_attempts
                    or elapsed + delay > policy.deadline_s):
                break
            if policy.on_wait is not None:
                policy.on_wait(delay)
            policy.sleep(delay)
            delay *= policy.backoff
    assert last is not None
    raise last
