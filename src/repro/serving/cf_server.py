"""Neighbourhood-CF recommendation server with the paper's TwinSearch
new-user onboarding fast path, hardened for bursty production traffic.

Request surface (what a real deployment fronts with an RPC layer):

  * ``onboard_user(ratings)``   — TwinSearch -> copy, or traditional build
                                  fallback; returns the new user id + info.
  * ``recommend(user, n)``      — top-n unseen items via kNN scores.
  * ``predict(user, item)``     — kNN weighted-average rating.
  * ``add_rating(user, item, r)``— incremental (Papagelis-style) update of
                                  the affected similarity row.

Resilience contract: **no public entrypoint raises to the caller.**

  * Malformed payloads (NaN/Inf, wrong shape/dtype, out-of-range, bogus
    ids) are refused by ``serving/guard.py`` before touching any jitted
    kernel and land in a bounded quarantine; the caller gets a structured
    refusal (``status="rejected"``).
  * Capacity exhaustion triggers **arena rotation**
    (``core/rotation.py``): the write region compacts into a larger base
    arena via PR 1's fused k-way merge — onboarding continues past the
    original ``capacity_extra`` indefinitely.  ``rotate_headroom`` scales
    the fresh write region with the absorbed burst (hysteresis against
    back-to-back synchronous rotations); each rotation's duration lands
    in ``ServerStats.rotation_ms``.
  * Onboard latencies feed a ``StragglerMonitor`` (``training/elastic.py``)
    driving a **degradation ladder**: twinsearch -> traditional ->
    degraded-replica -> shed-with-backpressure.  Latency verdicts walk
    twinsearch -> traditional -> shed directly; the ``degraded`` rung is
    entered when replication redundancy drops (a replica died) and pins
    the server at the traditional path until background re-replication
    restores r-way redundancy.  Every transition is counted in
    ``ServerStats``.
  * The jitted onboard call runs under retry-with-exponential-backoff and
    a deadline (transient executor faults); a call that still fails is
    quarantined, not raised (and its write-ahead record is aborted).

Durability contract: **a crash or a shard loss never forces a similarity
recompute.**

  * Every mutating op is appended to a **write-ahead log**
    (``serving/wal.py``, ``wal_dir``/``wal_fsync`` knobs) *before* it is
    applied; on restart ``CFServer.recover(...)`` replays the log on top
    of the newest durable checkpoint, reproducing the pre-crash arena
    bit-exactly.  The log truncates at each durable snapshot and rewinds
    on rollback, so it always holds exactly the ops since the state the
    next recovery would start from.
  * With ``replication=ReplicationConfig(...)`` the arena's row shards
    are mirrored r-way (``distributed/replication.py``).  A poisoned
    primary row — bit-flip, lost shard — is *healed* from a surviving
    replica (pure data movement) instead of rolled back; a lost replica
    is rebuilt from survivors incrementally between requests.  Rollback
    to the last good snapshot remains the backstop when no replica
    survives.
  * Periodic atomic **snapshots** (in-memory always; on disk via
    ``training/checkpoint.py`` when ``snapshot_dir`` is set, now with
    per-leaf CRC32 verification and fall-back-to-previous-step on
    corruption) pair with a cheap NaN/ordering invariant check
    (``kernels/verify_rows``) every ``check_every`` onboards.

State is the fixed-capacity ``CFState`` (jit-friendly); all mutating ops
are jitted once per arena shape and reused.  ``stats`` tracks twin hits /
fallbacks / latencies / resilience transitions — the serving-side
visibility the benchmarks read.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (CFState, build_state, knn, set0_cap)
from repro.core import baseline as base_lib
from repro.core import twinsearch as ts
from repro.core import update as upd_lib
from repro.core.rotation import rotate_arena
from repro.distributed.replication import ReplicatedArena, ReplicationConfig
from repro.kernels.verify_rows.ops import arena_healthy
from repro.serving import guard
from repro.serving.wal import WriteAheadLog
from repro.training import checkpoint
from repro.training.elastic import Action, StragglerMonitor

log = logging.getLogger(__name__)

# Degradation ladder levels (ascending = more degraded).
LEVEL_TWINSEARCH = 0
LEVEL_TRADITIONAL = 1
LEVEL_DEGRADED = 2          # replica redundancy lost; rebuilding in background
LEVEL_SHED = 3
LEVEL_NAMES = {LEVEL_TWINSEARCH: "twinsearch",
               LEVEL_TRADITIONAL: "traditional",
               LEVEL_DEGRADED: "degraded",
               LEVEL_SHED: "shed"}


@dataclass
class ServerStats:
    onboarded: int = 0
    twin_hits: int = 0
    fallbacks: int = 0
    overflows: int = 0
    rejected: int = 0
    shed: int = 0
    retries: int = 0
    errors: int = 0
    rotations: int = 0
    snapshots: int = 0
    rollbacks: int = 0
    repairs: int = 0            # poisoned rows healed from replicas
    degradations: int = 0
    recoveries: int = 0
    wal_appends: int = 0
    wal_replayed: int = 0
    latency_window: int = 1024
    onboard_ms: deque = field(init=False)
    rotation_ms: deque = field(init=False)

    def __post_init__(self) -> None:
        # Fixed-size ring buffers: sustained traffic must not grow host
        # memory; summary() percentiles are over the trailing window.
        self.onboard_ms = deque(maxlen=self.latency_window)
        self.rotation_ms = deque(maxlen=64)

    def summary(self) -> dict:
        ms = sorted(self.onboard_ms) or [0.0]
        rot = sorted(self.rotation_ms) or [0.0]
        return {
            "onboarded": self.onboarded,
            "twin_hits": self.twin_hits,
            "fallbacks": self.fallbacks,
            "overflows": self.overflows,
            "rejected": self.rejected,
            "shed": self.shed,
            "retries": self.retries,
            "errors": self.errors,
            "rotations": self.rotations,
            "snapshots": self.snapshots,
            "rollbacks": self.rollbacks,
            "repairs": self.repairs,
            "degradations": self.degradations,
            "recoveries": self.recoveries,
            "wal_appends": self.wal_appends,
            "wal_replayed": self.wal_replayed,
            "onboard_p50_ms": ms[len(ms) // 2],
            "onboard_p99_ms": ms[min(len(ms) - 1, int(len(ms) * 0.99))],
            "rotation_p50_ms": rot[len(rot) // 2],
            "rotation_max_ms": rot[-1],
        }


class CFServer:
    def __init__(self, ratings: np.ndarray, *, capacity_extra: int = 64,
                 c_probes: int = 8, sim_tol: float = 1e-6,
                 measure: str = "cosine", seed: int = 0,
                 rating_range: tuple[float, float] = (1.0, 5.0),
                 quarantine_capacity: int = 256,
                 latency_window: int = 1024,
                 retry: guard.RetryPolicy | None = None,
                 monitor: StragglerMonitor | None = None,
                 recover_after: int = 32,
                 shed_cooldown_s: float = 1.0,
                 snapshot_every: int = 64,
                 snapshot_dir: str | None = None,
                 snapshot_keep: int = 3,
                 check_every: int = 8,
                 rotate_headroom: float = 1.0,
                 wal_dir: str | None = None,
                 wal_fsync: bool = True,
                 replication: ReplicationConfig | None = None,
                 recover: bool = False):
        self.n_base = int(ratings.shape[0])
        self.k_cap = int(capacity_extra)
        self.c = c_probes
        self.tol = sim_tol
        self.rating_range = (float(rating_range[0]), float(rating_range[1]))
        self.rotate_headroom = float(rotate_headroom)
        self.state: CFState = jax.jit(
            lambda R: build_state(R, capacity_extra=capacity_extra,
                                  measure=measure))(jnp.asarray(
                                      ratings, jnp.float32))
        self._key = jax.random.PRNGKey(seed)
        self.stats = ServerStats(latency_window=latency_window)
        self.quarantine = guard.Quarantine(capacity=quarantine_capacity)

        # Degradation ladder + retry machinery.  The monitor's clock is the
        # server's time source for shed cooldowns too, so fault-injection
        # tests drive the whole ladder in virtual time.
        self.retry = retry or guard.RetryPolicy()
        self.monitor = monitor or StragglerMonitor(
            window=64, straggler_ratio=4.0, hang_timeout_s=30.0,
            consecutive_to_shrink=3)
        self._clock = self.monitor.clock
        self.level = LEVEL_TWINSEARCH
        self.recover_after = int(recover_after)
        self.shed_cooldown_s = float(shed_cooldown_s)
        self._healthy_streak = 0
        self._shed_until = 0.0

        # Snapshot / rollback machinery.
        self.snapshot_every = int(snapshot_every)
        self.snapshot_dir = snapshot_dir
        self.snapshot_keep = int(snapshot_keep)
        self.check_every = int(check_every)
        self._since_snapshot = 0
        self._since_check = 0

        # Durability machinery.  ``_seq`` is the monotonic mutation counter:
        # it numbers WAL records AND disk checkpoints, so "checkpoint at S
        # plus WAL records with seq > S" is always the current state.
        self._seq = 0
        self.wal = (WriteAheadLog(wal_dir, fsync=wal_fsync)
                    if wal_dir is not None else None)
        self._replaying = False
        self._crash_hook = None        # test seam: see testing/faults.py
        self.replicas: ReplicatedArena | None = None

        # All jitted entrypoints are constructed eagerly (construction is
        # free — tracing happens on first call) so a first-call exception
        # can never leave the server half-initialised; the update cache is
        # still *computed* lazily (it is O(N^2) memory).
        self._cache = None
        self._build_jits()

        if recover:
            self._recover_state()

        if replication is not None:
            self.replicas = ReplicatedArena(self.state, replication)

        self._snapshot = None
        self._take_snapshot()            # the construction-time good state

    @classmethod
    def recover(cls, ratings: np.ndarray, **kwargs) -> "CFServer":
        """Rebuild a server after a crash: restore the newest durable
        checkpoint under ``snapshot_dir`` (falling back past corrupt
        steps), then replay the WAL suffix under ``wal_dir`` through the
        same jitted ops — the recovered arena is bit-identical to the
        pre-crash one, with zero similarity recompute.  Pass the same
        construction knobs as the original server."""
        kwargs["recover"] = True
        return cls(ratings, **kwargs)

    # -- internal machinery -------------------------------------------------

    def _build_jits(self) -> None:
        """(Re)wrap the jitted ops for the *current* arena geometry.
        Called at construction and after every rotation/rollback/restore —
        the closures capture ``n_base``/``s_max``/``k_cap``, which those
        transitions change."""
        self.s_max = set0_cap(self.n_base)
        n_base, k_cap = self.n_base, self.k_cap
        self._onboard = jax.jit(lambda st, r0, probes: ts.onboard_twinsearch(
            st, r0, probes, s_max=self.s_max, n_base=n_base,
            k_cap=k_cap, tol=self.tol))
        self._onboard_trad = jax.jit(base_lib.onboard_traditional)
        self._recommend = jax.jit(knn.recommend,
                                  static_argnames=("k_neighbors", "n_rec"))
        self._predict = jax.jit(knn.predict, static_argnames=("k",))
        self._init_cache = jax.jit(upd_lib.init_cache)
        self._add = jax.jit(upd_lib.add_rating)
        self._healthy = arena_healthy

    def _reject(self, kind: str, reason: str, payload=None,
                detail: str = "") -> dict:
        self.stats.rejected += 1
        self.quarantine.record(kind, reason, payload, detail)
        return {"status": "rejected", "reason": reason}

    def _crashpoint(self, name: str) -> None:
        """Deterministic crash injection seam (``testing/faults.py``
        installs the hook); a no-op in production."""
        if self._crash_hook is not None:
            self._crash_hook(name)

    # -- degradation ladder -------------------------------------------------

    def _replicas_degraded(self) -> bool:
        return self.replicas is not None and self.replicas.degraded()

    def _set_level(self, level: int) -> None:
        if level == self.level:
            return
        if level > self.level:
            self.stats.degradations += 1
            log.warning("degrading %s -> %s", LEVEL_NAMES[self.level],
                        LEVEL_NAMES[level])
        else:
            self.stats.recoveries += 1
            log.info("recovering %s -> %s", LEVEL_NAMES[self.level],
                     LEVEL_NAMES[level])
        self.level = level
        self._healthy_streak = 0
        if level == LEVEL_SHED:
            self._shed_until = self._clock() + self.shed_cooldown_s

    def _step_down(self) -> None:
        """One recovery step down the ladder.  The ``degraded`` rung is
        owned by replication: stepping out of SHED lands on it while
        redundancy is still lost, and the rung itself is pinned until
        re-replication completes (``_replication_tick`` releases it)."""
        if self.level == LEVEL_SHED:
            self._set_level(LEVEL_DEGRADED if self._replicas_degraded()
                            else LEVEL_TRADITIONAL)
        elif self.level == LEVEL_DEGRADED:
            if not self._replicas_degraded():
                self._set_level(LEVEL_TRADITIONAL)
        else:
            self._set_level(max(LEVEL_TWINSEARCH, self.level - 1))

    def _apply_monitor(self, action: Action) -> None:
        if action is Action.ABORT:
            # A hang-scale latency: shed immediately, don't walk the ladder.
            self._set_level(LEVEL_SHED)
        elif action is Action.CHECKPOINT_AND_SHRINK:
            # Latency verdicts walk twinsearch -> traditional -> shed; the
            # degraded rung is entered only by replica-loss events.
            self._set_level(LEVEL_TRADITIONAL
                            if self.level == LEVEL_TWINSEARCH
                            else LEVEL_SHED)
        else:
            self._healthy_streak += 1
            if (self.level > LEVEL_TWINSEARCH
                    and self._healthy_streak >= self.recover_after):
                self._step_down()

    def _replication_tick(self) -> None:
        """Per-request background replication work: advance re-replication
        by the configured row budget and keep the ladder's ``degraded``
        rung in sync with actual redundancy."""
        if self.replicas is None:
            return
        self.replicas.step_rebuild()
        if self.replicas.degraded():
            if self.level < LEVEL_DEGRADED:
                self._set_level(LEVEL_DEGRADED)
        elif self.level == LEVEL_DEGRADED:
            self._set_level(LEVEL_TRADITIONAL)

    # -- rotation -----------------------------------------------------------

    def _rotate(self) -> None:
        """Grow the arena: compact the write region into a new base (see
        ``core/rotation.py``) and retarget every jitted op at the new
        geometry.  The incremental-update cache keys on the old shapes and
        is dropped; replicas re-mirror the new geometry."""
        old_capacity = self.state.capacity
        t0 = time.perf_counter()
        self.state = rotate_arena(self.state, n_base=self.n_base,
                                  extra=self.k_cap,
                                  headroom=self.rotate_headroom)
        self.state.sim_vals.block_until_ready()
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.n_base = int(self.state.n_active)
        self.k_cap = self.state.capacity - self.n_base
        self._cache = None
        self._build_jits()
        self.stats.rotations += 1
        self.stats.rotation_ms.append(dt_ms)
        if self.replicas is not None:
            self.replicas.reset(self.state)
        log.info("arena rotated: capacity %d -> %d (n_base=%d, %.1fms)",
                 old_capacity, self.state.capacity, self.n_base, dt_ms)

    # -- durability: WAL / snapshot / rollback / recovery -------------------

    def _log(self, op: str, fields: dict | None = None,
             arrays: dict | None = None) -> int:
        """Assign the next mutation sequence number and (when a WAL is
        attached and we are not replaying) append the record *before* the
        op is applied — the write-ahead contract."""
        self._seq += 1
        if self.wal is not None and not self._replaying:
            self.wal.append(self._seq, op, fields, arrays)
            self.stats.wal_appends += 1
        return self._seq

    def _take_snapshot(self) -> None:
        self._snapshot = (self.state, self.n_base, self._key, self._seq)
        self.stats.snapshots += 1
        self._since_snapshot = 0
        if self.snapshot_dir is not None:
            checkpoint.save(self.snapshot_dir, self._seq, self.state,
                            extra={"n_base": self.n_base,
                                   "key": np.asarray(self._key).tolist(),
                                   "wal_seq": self._seq},
                            keep_last=self.snapshot_keep)
            if self.wal is not None:
                # The checkpoint subsumes every logged op; drop them.  The
                # incremental dots cache is re-seeded at this boundary so a
                # replayed timeline (which must init it from the restored
                # ratings) stays bit-identical to the live one.
                self.wal.truncate_through(self._seq)
                self._cache = None

    def _rollback(self) -> None:
        state, n_base, key, seq = self._snapshot
        geometry_changed = (state.capacity != self.state.capacity
                            or n_base != self.n_base)
        self.state, self.n_base, self._key = state, n_base, key
        self.k_cap = state.capacity - n_base
        self._seq = seq
        self._cache = None
        if geometry_changed:
            self._build_jits()
        if self.wal is not None:
            self.wal.truncate_after(seq)
        if self.replicas is not None:
            self.replicas.reset(self.state)
        self.stats.rollbacks += 1
        self._since_check = 0
        self._since_snapshot = 0
        log.error("arena invariant violated; rolled back to last good "
                  "snapshot (n_active=%d)", int(state.n_active))

    def _recover_state(self) -> None:
        """Restore the newest loadable checkpoint, then replay the WAL
        suffix.  Zero similarity math: the checkpoint is a byte copy and
        replay re-runs only the logged (cheap) maintenance ops."""
        restored = False
        fell_back = False
        if self.snapshot_dir is not None:
            try:
                tree, step, extra = checkpoint.restore(self.snapshot_dir,
                                                       self.state)
            except FileNotFoundError:
                pass
            else:
                self.state = tree
                self.n_base = int(extra.get("n_base", self.n_base))
                self.k_cap = self.state.capacity - self.n_base
                if "key" in extra:
                    self._key = jnp.asarray(extra["key"], jnp.uint32)
                self._seq = int(extra.get("wal_seq", step))
                self._cache = None
                self._build_jits()
                restored = True
                newest = checkpoint.latest_step(self.snapshot_dir)
                fell_back = newest is not None and newest > step
                log.info("restored checkpoint step %d (n_active=%d)",
                         step, int(self.state.n_active))
        if self.wal is not None:
            # Gap checks run on the WAL's *raw* sequence bounds — aborted
            # ops and their compensation records count (records() filters
            # them out of the replay stream, but their seqs were consumed):
            # an aborted prefix is not a missing prefix, and replaying over
            # a genuinely missing one would silently drop committed ops.
            first_raw = self.wal.first_seq
            if not restored:
                if first_raw > 1:
                    raise RuntimeError(
                        f"WAL starts at seq {first_raw} but no checkpoint "
                        f"could be restored — earlier ops were truncated "
                        f"into a checkpoint that is now missing or corrupt")
            elif (first_raw > self._seq + 1
                    or (fell_back and first_raw == 0)):
                # The newest checkpoint was corrupt and the WAL was already
                # truncated through it: the ops between the fallback step
                # and the corrupt one are unrecoverable.  (A crash between
                # checkpoint.save and the WAL truncation leaves the suffix
                # intact — first_seq <= wal_seq + 1 — and recovers fine.)
                raise RuntimeError(
                    f"restored checkpoint is at seq {self._seq} but the WAL "
                    f"{'is empty' if first_raw == 0 else f'starts at seq {first_raw}'}"
                    f" — ops since seq {self._seq} were truncated into a "
                    f"newer checkpoint that is corrupt; refusing to replay "
                    f"over the gap")
            self._replay(self.wal.records(after_seq=self._seq))
            # Resume numbering past the raw WAL tail: an aborted tail op's
            # seq (and its abort record's) never replays, but reissuing it
            # would make records() drop the next committed op as aborted on
            # a later recovery.
            self._seq = max(self._seq, self.wal.last_seq)

    def _replay(self, records) -> None:
        self._replaying = True
        try:
            for rec in records:
                self._seq = rec.seq
                if rec.op == "rotate":
                    self._rotate()
                elif rec.op == "onboard":
                    self._replay_onboard(rec)
                elif rec.op == "add_rating":
                    self._replay_add_rating(rec)
                else:
                    log.warning("unknown WAL op %r at seq %d skipped",
                                rec.op, rec.seq)
                self.stats.wal_replayed += 1
        finally:
            self._replaying = False

    def _replay_onboard(self, rec) -> None:
        r0 = jnp.asarray(rec.arrays["ratings"].astype(np.float32))
        use_twin = bool(rec.fields.get("use_twin", False))
        if use_twin:
            # Advance the PRNG stream exactly as the live path did; the
            # recorded probes equal the re-derived ones, but the record is
            # authoritative (recovery works even from a foreign key state).
            self._key, _ = jax.random.split(self._key)
            probes = jnp.asarray(rec.arrays["probes"])
            new_state, res = self._onboard(self.state, r0, probes)
            found, overflowed = bool(res.found), bool(res.overflowed)
        else:
            new_state = self._onboard_trad(self.state, r0)
            found = overflowed = False
        new_state.n_active.block_until_ready()
        self._commit_onboard(new_state, found, overflowed)

    def _replay_add_rating(self, rec) -> None:
        f = rec.fields
        self._apply_add_rating(int(f["user"]), int(f["item"]),
                               float(f["rating"]))

    # -- health check + snapshot cadence ------------------------------------

    def _state_ok(self) -> bool:
        """Verify the arena invariant; heal poisoned rows from replicas
        (exact, similarity-free) when possible, roll back to the last good
        snapshot otherwise.  False iff a rollback happened."""
        if bool(self._healthy(self.state.sim_vals, self.state.ratings,
                              self.state.norms, self.state.n_active)):
            return True
        if self.replicas is not None:
            fixed, rows = self.replicas.repair(self.state)
            if fixed is not None and bool(self._healthy(
                    fixed.sim_vals, fixed.ratings, fixed.norms,
                    fixed.n_active)):
                self.state = fixed
                self._cache = None
                self.stats.repairs += 1
                log.warning("healed %d poisoned arena rows from replicas",
                            len(rows))
                return True
        self._rollback()
        return False

    def _check_and_snapshot(self) -> bool:
        """Periodic poison detection + snapshot cadence.  Returns False if
        the current state failed the invariant and was rolled back (a
        replica-healed state counts as healthy)."""
        self._since_check += 1
        self._since_snapshot += 1
        if self._since_check >= self.check_every:
            self._since_check = 0
            if self.replicas is not None:
                self.replicas.sweep()
            if not self._state_ok():
                return False
        if self._since_snapshot >= self.snapshot_every:
            # Never snapshot unverified state: a snapshot of a poisoned
            # arena would poison every future rollback.
            if bool(self._healthy(self.state.sim_vals, self.state.ratings,
                                  self.state.norms, self.state.n_active)):
                self._take_snapshot()
        return True

    # -- onboarding ---------------------------------------------------------

    def _commit_onboard(self, new_state: CFState, found: bool,
                        overflowed: bool) -> None:
        self.state = new_state
        self.stats.onboarded += 1
        self.stats.twin_hits += found
        self.stats.fallbacks += not found
        self.stats.overflows += overflowed
        if self.replicas is not None:
            self.replicas.apply_rows([int(new_state.n_active) - 1],
                                     new_state)

    def onboard_user(self, ratings: np.ndarray, *,
                     use_twinsearch: bool = True) -> tuple[int, dict]:
        reason = guard.validate_ratings_vector(
            ratings, n_items=self.state.n_items,
            rating_range=self.rating_range)
        if reason is not None:
            return -1, {**self._reject("onboard", reason, ratings),
                        "twin_found": False}

        self._replication_tick()
        if self.level == LEVEL_SHED:
            if self._clock() < self._shed_until:
                self.stats.shed += 1
                return -1, {"status": "shed", "twin_found": False,
                            "retry_after_s": self._shed_until - self._clock()}
            # Cooldown expired: probe the cheaper build path again.
            self._set_level(LEVEL_DEGRADED if self._replicas_degraded()
                            else LEVEL_TRADITIONAL)

        self._crashpoint("onboard.pre_wal")
        if int(self.state.n_active) >= self.state.capacity:
            self._log("rotate")
            self._crashpoint("rotate.post_wal")
            self._rotate()

        r0_np = np.asarray(ratings, dtype=np.float32)
        r0 = jnp.asarray(r0_np)
        use_twin = use_twinsearch and self.level == LEVEL_TWINSEARCH
        if use_twin:
            self._key, sub = jax.random.split(self._key)
            probes = jax.random.randint(sub, (self.c,), 0, self.n_base)

            def run():
                new_state, res = self._onboard(self.state, r0, probes)
                new_state.n_active.block_until_ready()
                return new_state, bool(res.found), bool(res.overflowed)
        else:
            probes = None

            def run():
                new_state = self._onboard_trad(self.state, r0)
                new_state.n_active.block_until_ready()
                return new_state, False, False

        seq = self._log(
            "onboard", fields={"use_twin": bool(use_twin)},
            arrays={"ratings": r0_np,
                    "probes": (np.asarray(probes) if probes is not None
                               else np.empty((0,), np.int32))})
        self._crashpoint("onboard.post_wal")

        self.monitor.step_started()
        t0 = time.perf_counter()
        try:
            (new_state, found, overflowed), retries = guard.call_with_retry(
                run, self.retry)
        except Exception as e:          # noqa: BLE001 — contract: no raise
            self.monitor.step_finished()
            self.stats.errors += 1
            # Compensate the write-ahead record: the op never applied, so
            # replay must skip it.
            self._log("abort", fields={"target": seq})
            self.quarantine.record("onboard", guard.R_ERROR, ratings,
                                   detail=repr(e))
            log.error("onboard failed after retries: %r", e)
            return -1, {"status": "error", "reason": guard.R_ERROR,
                        "twin_found": False, "detail": repr(e)}
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._apply_monitor(self.monitor.step_finished())

        self.stats.retries += retries
        self._commit_onboard(new_state, found, overflowed)
        self.stats.onboard_ms.append(dt_ms)
        self._crashpoint("onboard.post_commit")

        if not self._check_and_snapshot():
            return -1, {"status": "rolled_back", "twin_found": False,
                        "ms": dt_ms}
        uid = int(self.state.n_active) - 1
        return uid, {"status": "ok", "twin_found": found, "ms": dt_ms,
                     "level": LEVEL_NAMES[self.level]}

    # -- queries ------------------------------------------------------------

    def recommend(self, user: int, n: int = 10,
                  k_neighbors: int = 20) -> list[tuple[int, float]]:
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("recommend", guard.R_USER_ID, user)
            return []
        if self.replicas is not None:
            # Failover read: heal any poisoned rows from replicas before
            # answering, so a lost shard degrades durability, not answers.
            self._replication_tick()
            self._state_ok()
        scores, items = self._recommend(self.state, jnp.int32(user),
                                        k_neighbors=k_neighbors, n_rec=n)
        return [(int(i), float(s)) for s, i in zip(scores, items)]

    def predict(self, user: int, item: int, k: int = 20) -> float:
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("predict", guard.R_USER_ID, user)
            return 0.0
        if guard.validate_item_id(item, self.state.n_items):
            self._reject("predict", guard.R_ITEM_ID, item)
            return 0.0
        if self.replicas is not None:
            self._replication_tick()
            self._state_ok()
        return float(self._predict(self.state, jnp.int32(user),
                                   jnp.int32(item), k=k))

    # -- maintenance --------------------------------------------------------

    def _apply_add_rating(self, user: int, item: int,
                          rating: float) -> None:
        if self._cache is None:
            self._cache = self._init_cache(self.state.ratings)
        self.state, self._cache = self._add(
            self.state, self._cache, jnp.int32(user), jnp.int32(item),
            jnp.float32(rating))
        if self.replicas is not None:
            self.replicas.apply_rows([user], self.state)

    def add_rating(self, user: int, item: int, rating: float) -> bool:
        """Returns True iff the update was applied (False = quarantined)."""
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("add_rating", guard.R_USER_ID, user)
            return False
        if guard.validate_item_id(item, self.state.n_items):
            self._reject("add_rating", guard.R_ITEM_ID, item)
            return False
        reason = guard.validate_rating_value(rating, self.rating_range)
        if reason is not None:
            self._reject("add_rating", reason, rating)
            return False
        self._replication_tick()
        self._crashpoint("add_rating.pre_wal")
        self._log("add_rating", fields={"user": int(user), "item": int(item),
                                        "rating": float(rating)})
        self._crashpoint("add_rating.post_wal")
        self._apply_add_rating(int(user), int(item), float(rating))
        self._crashpoint("add_rating.post_commit")
        return True
