"""Neighbourhood-CF recommendation server with the paper's TwinSearch
new-user onboarding fast path, hardened for bursty production traffic.

Request surface (what a real deployment fronts with an RPC layer):

  * ``onboard_user(ratings)``   — TwinSearch -> copy, or traditional build
                                  fallback; returns a typed
                                  ``OnboardResult`` (legacy
                                  ``(uid, info)`` unpacking still works).
  * ``onboard_batch(batch)``    — a sequence of onboards under one WAL
                                  group commit (one fsync per batch).
  * ``recommend(user, n)``      — top-n unseen items via kNN scores.
  * ``predict(user, item)``     — kNN weighted-average rating.
  * ``recommend_batch(users)``  — B recommendations in one device
                                  dispatch: per-row guard validation
                                  (a bad row is quarantined, the rest are
                                  served), twin-query dedup before
                                  dispatch, one host transfer of results.
  * ``predict_batch(users, items)`` — B predictions, same contract.
  * ``add_rating(user, item, r)``— incremental (Papagelis-style) update of
                                  the affected similarity row.
  * ``step_maintenance()``      — drain a slice of any pending incremental
                                  rotation during quiet periods.

Configuration is a frozen ``serving.ServerConfig`` (sub-configs:
``SnapshotConfig`` / ``WalConfig`` / ``RotationConfig`` / ``LadderConfig``);
the historical flat kwargs still work via a deprecation shim.

With ``RotationConfig.budget_rows > 0`` arena rotation is *incremental*:
a ``RotationPlan`` starts when free write slots fall to ``reserve_slots``
and merges at most ``budget_rows`` base rows per onboard/tick (plus retry
backoff waits and shed backpressure windows), while new users keep
landing in the buffer past the frozen boundary; the final atomic swap is
bit-identical to the synchronous rotation of the live state and is the
only part a request ever waits for (``ServerStats.rotation_pause_ms``).
The swap is WAL-logged as ``rotate_commit`` (frozen boundary + growth),
so recovery replays it deterministically via ``rotate_arena_frozen``.

Resilience contract: **no public entrypoint raises to the caller.**

  * Malformed payloads (NaN/Inf, wrong shape/dtype, out-of-range, bogus
    ids) are refused by ``serving/guard.py`` before touching any jitted
    kernel and land in a bounded quarantine; the caller gets a structured
    refusal (``status="rejected"``).
  * Capacity exhaustion triggers **arena rotation**
    (``core/rotation.py``): the write region compacts into a larger base
    arena via PR 1's fused k-way merge — onboarding continues past the
    original ``capacity_extra`` indefinitely.  ``rotate_headroom`` scales
    the fresh write region with the absorbed burst (hysteresis against
    back-to-back synchronous rotations); each rotation's duration lands
    in ``ServerStats.rotation_ms``.
  * Onboard latencies feed a ``StragglerMonitor`` (``training/elastic.py``)
    driving a **degradation ladder**: twinsearch -> traditional ->
    degraded-replica -> shed-with-backpressure.  Latency verdicts walk
    twinsearch -> traditional -> shed directly; the ``degraded`` rung is
    entered when replication redundancy drops (a replica died) and pins
    the server at the traditional path until background re-replication
    restores r-way redundancy.  Every transition is counted in
    ``ServerStats``.
  * The jitted onboard call runs under retry-with-exponential-backoff and
    a deadline (transient executor faults); a call that still fails is
    quarantined, not raised (and its write-ahead record is aborted).

Durability contract: **a crash or a shard loss never forces a similarity
recompute.**

  * Every mutating op is appended to a **write-ahead log**
    (``serving/wal.py``, ``wal_dir``/``wal_fsync`` knobs) *before* it is
    applied; on restart ``CFServer.recover(...)`` replays the log on top
    of the newest durable checkpoint, reproducing the pre-crash arena
    bit-exactly.  The log truncates at each durable snapshot and rewinds
    on rollback, so it always holds exactly the ops since the state the
    next recovery would start from.
  * With ``replication=ReplicationConfig(...)`` the arena's row shards
    are mirrored r-way (``distributed/replication.py``).  A poisoned
    primary row — bit-flip, lost shard — is *healed* from a surviving
    replica (pure data movement) instead of rolled back; a lost replica
    is rebuilt from survivors incrementally between requests.  Rollback
    to the last good snapshot remains the backstop when no replica
    survives.
  * Periodic atomic **snapshots** (in-memory always; on disk via
    ``training/checkpoint.py`` when ``snapshot_dir`` is set, now with
    per-leaf CRC32 verification and fall-back-to-previous-step on
    corruption) pair with a cheap NaN/ordering invariant check
    (``kernels/verify_rows``) every ``check_every`` onboards.

Query contract: **reads are never refused.**  The batch endpoints
validate per row — a malformed row is quarantined and its slot answers
empty/0.0 while the rest of the batch is served — and the degradation
ladder's shed rung *degrades* queries (``k_neighbors`` drops by
``SHED_QUERY_K_DIV``) instead of shedding them: a read is cheaper than
the refusal dance.  Before dispatch, **twin-query dedup**
(``serving/dedup.py``) collapses rows whose scoring inputs — top-k
neighbour sims + ids and, for recommendations, the user's own rating
row — are bitwise identical: the paper's twins share similarity lists,
so they provably share recommendation scores, and only the unique rows
are scored (``ServerStats.query_dedup_savings``).  Unique-row and batch
shapes are bucketed to powers of two so the jitted query programs are
compile-once per bucket, and each batch pays exactly two host transfers
(the probe for dedup keys, the fanned-out results).

State is the fixed-capacity ``CFState`` (jit-friendly); all mutating ops
are jitted once per arena shape and reused.  ``stats`` tracks twin hits /
fallbacks / latencies / resilience transitions — the serving-side
visibility the benchmarks read.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import math
import time
import warnings
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (CFState, build_state, knn, set0_cap)
from repro.core import baseline as base_lib
from repro.core import twinsearch as ts
from repro.core import update as upd_lib
from repro.core.rotation import (RotationPlan, rotate_arena,
                                 rotate_arena_frozen)
from repro.distributed.replication import ReplicatedArena, ReplicationConfig
from repro.kernels.knn_score.ops import knn_recommend_topn
from repro.kernels.verify_rows.ops import arena_healthy
from repro.serving import guard
from repro.serving.dedup import dedup_rows
from repro.serving.config import ServerConfig
from repro.serving.wal import WriteAheadLog
from repro.training import checkpoint
from repro.training.elastic import Action, StragglerMonitor

log = logging.getLogger(__name__)

# Degradation ladder levels (ascending = more degraded).
LEVEL_TWINSEARCH = 0
LEVEL_TRADITIONAL = 1
LEVEL_DEGRADED = 2          # replica redundancy lost; rebuilding in background
LEVEL_SHED = 3
LEVEL_NAMES = {LEVEL_TWINSEARCH: "twinsearch",
               LEVEL_TRADITIONAL: "traditional",
               LEVEL_DEGRADED: "degraded",
               LEVEL_SHED: "shed"}

# Shed-rung query degradation: reads are served with k_neighbors // this
# (floor 1) instead of being refused — the ladder's read-path analogue of
# the twinsearch -> traditional write-path fallback.
SHED_QUERY_K_DIV = 4


def _bucket_pow2(n: int) -> int:
    """Smallest power of two >= n — the jit-cache shape bucket for the
    variable-size query batches (bounded recompiles, fixed shapes)."""
    return 1 << max(0, n - 1).bit_length()


@dataclass
class ServerStats:
    onboarded: int = 0
    twin_hits: int = 0
    fallbacks: int = 0
    overflows: int = 0
    rejected: int = 0
    shed: int = 0
    retries: int = 0
    errors: int = 0
    rotations: int = 0
    snapshots: int = 0
    rollbacks: int = 0
    repairs: int = 0            # poisoned rows healed from replicas
    degradations: int = 0
    recoveries: int = 0
    wal_appends: int = 0
    wal_replayed: int = 0
    plan_restarts: int = 0      # incremental-rotation precompute restarts
    forced_drains: int = 0      # buffer filled before the plan finished
    queries: int = 0            # query rows served (valid rows only)
    query_batches: int = 0      # recommend_batch / predict_batch calls
    query_unique: int = 0       # rows actually scored after twin dedup
    query_degraded: int = 0     # rows served at shed-reduced k_neighbors
    latency_window: int = 1024
    onboard_ms: deque = field(init=False)
    rotation_ms: deque = field(init=False)
    rotation_pause_ms: deque = field(init=False)
    query_ms: deque = field(init=False)
    query_dedup_savings: deque = field(init=False)

    def __post_init__(self) -> None:
        # Fixed-size ring buffers: sustained traffic must not grow host
        # memory; summary() percentiles are over the trailing window.
        self.onboard_ms = deque(maxlen=self.latency_window)
        self.rotation_ms = deque(maxlen=64)
        # What rotation actually cost a *single request*: the synchronous
        # stall (full rotation, or just the final swap when incremental).
        self.rotation_pause_ms = deque(maxlen=64)
        # Per-batch query latency + twin-dedup savings fraction (the
        # trailing-window view; queries/query_unique are the totals).
        self.query_ms = deque(maxlen=self.latency_window)
        self.query_dedup_savings = deque(maxlen=self.latency_window)

    def summary(self) -> dict:
        ms = sorted(self.onboard_ms) or [0.0]
        rot = sorted(self.rotation_ms) or [0.0]
        qms = sorted(self.query_ms) or [0.0]
        return {
            "onboarded": self.onboarded,
            "twin_hits": self.twin_hits,
            "fallbacks": self.fallbacks,
            "overflows": self.overflows,
            "rejected": self.rejected,
            "shed": self.shed,
            "retries": self.retries,
            "errors": self.errors,
            "rotations": self.rotations,
            "snapshots": self.snapshots,
            "rollbacks": self.rollbacks,
            "repairs": self.repairs,
            "degradations": self.degradations,
            "recoveries": self.recoveries,
            "wal_appends": self.wal_appends,
            "wal_replayed": self.wal_replayed,
            "plan_restarts": self.plan_restarts,
            "forced_drains": self.forced_drains,
            "onboard_p50_ms": ms[len(ms) // 2],
            "onboard_p99_ms": ms[min(len(ms) - 1, int(len(ms) * 0.99))],
            "rotation_p50_ms": rot[len(rot) // 2],
            "rotation_max_ms": rot[-1],
            "rotation_pause_max_ms": max(self.rotation_pause_ms, default=0.0),
            "queries": self.queries,
            "query_batches": self.query_batches,
            "query_unique": self.query_unique,
            "query_degraded": self.query_degraded,
            "query_p50_ms": qms[len(qms) // 2],
            "query_p99_ms": qms[min(len(qms) - 1, int(len(qms) * 0.99))],
            "query_dedup_savings": (1.0 - self.query_unique
                                    / max(self.queries, 1)),
        }


# Legacy dict-key -> OnboardResult attribute (identity for the rest).
_RESULT_KEY_MAP = {"ms": "latency_ms", "level": "rung"}


@dataclass(frozen=True)
class OnboardResult:
    """Typed outcome of ``onboard_user`` / ``onboard_batch``.

    Replaces the historical ``(user_id, info_dict)`` tuple.  For migration
    the old shapes still work: iterating yields ``(user_id, result)`` so
    ``uid, info = srv.onboard_user(r)`` unpacks as before, and
    ``result["ms"]`` / ``result["level"]`` / ``result.get(...)`` resolve
    through the legacy key names (``ms`` -> ``latency_ms``, ``level`` ->
    ``rung``).
    """
    user_id: int = -1
    status: str = "ok"        # ok|rejected|shed|error|rolled_back
    rung: str = "twinsearch"  # ladder level the request was served at
    latency_ms: float = 0.0
    rotated: bool = False     # this request triggered/absorbed a rotation
    seq: int = -1             # WAL sequence number (-1: nothing logged)
    twin_found: bool = False
    reason: str | None = None
    detail: str | None = None
    retry_after_s: float | None = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    # -- legacy (user_id, info_dict) compatibility --------------------------

    def __iter__(self):
        yield self.user_id
        yield self

    def __getitem__(self, key):
        if isinstance(key, int):
            return (self.user_id, self)[key]
        try:
            return getattr(self, _RESULT_KEY_MAP.get(key, key))
        except AttributeError:
            raise KeyError(key) from None

    def get(self, key, default=None):
        try:
            val = self[key]
        except KeyError:
            return default
        return default if val is None else val

    def __contains__(self, key) -> bool:
        try:
            return self[key] is not None
        except KeyError:
            return False


class CFServer:
    def __init__(self, ratings: np.ndarray,
                 config: ServerConfig | None = None, *,
                 recover: bool = False, **legacy):
        """``CFServer(ratings, config=ServerConfig(...))`` is the surface;
        the historical flat kwargs (``capacity_extra=..., wal_dir=...``)
        still work through a shim that round-trips them into a
        ``ServerConfig`` and emits a ``DeprecationWarning``."""
        if config is not None and legacy:
            raise ValueError(
                "pass either config=ServerConfig(...) or the legacy flat "
                f"kwargs, not both (got legacy keys {sorted(legacy)})")
        if config is None:
            if legacy:
                warnings.warn(
                    "CFServer's flat keyword arguments are deprecated; "
                    "pass config=ServerConfig(...) (see "
                    "repro.serving.config, ServerConfig.from_kwargs maps "
                    "the old names)", DeprecationWarning, stacklevel=2)
            config = ServerConfig.from_kwargs(**legacy)
        self.config = config
        self._rcfg = config.rotation
        self._wcfg = config.wal
        self._lcfg = config.ladder

        self.n_base = int(ratings.shape[0])
        self.k_cap = int(config.capacity_extra)
        self.c = config.c_probes
        self.tol = config.sim_tol
        self.rating_range = (float(config.rating_range[0]),
                             float(config.rating_range[1]))
        self.rotate_headroom = float(config.rotation.headroom)
        self.state: CFState = jax.jit(
            lambda R: build_state(R, capacity_extra=config.capacity_extra,
                                  measure=config.measure))(jnp.asarray(
                                      ratings, jnp.float32))
        self._key = jax.random.PRNGKey(config.seed)
        self.stats = ServerStats(latency_window=config.latency_window)
        self.quarantine = guard.Quarantine(
            capacity=config.quarantine_capacity)

        # Degradation ladder + retry machinery.  The monitor's clock is the
        # server's time source for shed cooldowns too, so fault-injection
        # tests drive the whole ladder in virtual time.  Retry backoff
        # waits double as maintenance ticks: time spent blocked on a
        # transient fault drains the rotation plan instead of idling.
        self.retry = config.ladder.retry or guard.RetryPolicy()
        if self.retry.on_wait is None:
            self.retry = dataclasses.replace(
                self.retry, on_wait=self._drain_during_wait)
        self.monitor = config.ladder.monitor or StragglerMonitor(
            window=64, straggler_ratio=4.0, hang_timeout_s=30.0,
            consecutive_to_shrink=3)
        self._clock = self.monitor.clock
        self.level = LEVEL_TWINSEARCH
        self.recover_after = int(config.ladder.recover_after)
        self.shed_cooldown_s = float(config.ladder.shed_cooldown_s)
        self._healthy_streak = 0
        self._shed_until = 0.0

        # Snapshot / rollback machinery.
        self.snapshot_every = int(config.snapshot.every)
        self.snapshot_dir = config.snapshot.dir
        self.snapshot_keep = int(config.snapshot.keep)
        self.check_every = int(config.snapshot.check_every)
        self._since_snapshot = 0
        self._since_check = 0

        # Incremental rotation: a pending chunked plan (None = no rotation
        # in flight; always None when rotation.budget_rows == 0).
        self._plan: RotationPlan | None = None

        # Durability machinery.  ``_seq`` is the monotonic mutation counter:
        # it numbers WAL records AND disk checkpoints, so "checkpoint at S
        # plus WAL records with seq > S" is always the current state.
        self._seq = 0
        self.wal = (WriteAheadLog(config.wal.dir, fsync=config.wal.fsync)
                    if config.wal.dir is not None else None)
        self._replaying = False
        self._crash_hook = None        # test seam: see testing/faults.py
        self.replicas: ReplicatedArena | None = None

        # All jitted entrypoints are constructed eagerly (construction is
        # free — tracing happens on first call) so a first-call exception
        # can never leave the server half-initialised; the update cache is
        # still *computed* lazily (it is O(N^2) memory).
        self._cache = None
        self._build_jits()

        if recover:
            self._recover_state()

        if config.replication is not None:
            self.replicas = ReplicatedArena(self.state, config.replication)

        self._snapshot = None
        self._take_snapshot()            # the construction-time good state

    @classmethod
    def recover(cls, ratings: np.ndarray,
                config: ServerConfig | None = None,
                **kwargs) -> "CFServer":
        """Rebuild a server after a crash: restore the newest durable
        checkpoint under the snapshot dir (falling back past corrupt
        steps), then replay the WAL suffix through the same jitted ops —
        the recovered arena is bit-identical to the pre-crash one, with
        zero similarity recompute.  Pass the same construction config as
        the original server."""
        return cls(ratings, config, recover=True, **kwargs)

    # -- internal machinery -------------------------------------------------

    def _build_jits(self) -> None:
        """(Re)wrap the jitted ops for the *current* arena geometry.
        Called at construction and after every rotation/rollback/restore —
        the closures capture ``n_base``/``s_max``/``k_cap``, which those
        transitions change."""
        self.s_max = set0_cap(self.n_base)
        n_base, k_cap = self.n_base, self.k_cap
        self._onboard = jax.jit(lambda st, r0, probes: ts.onboard_twinsearch(
            st, r0, probes, s_max=self.s_max, n_base=n_base,
            k_cap=k_cap, tol=self.tol))
        self._onboard_trad = jax.jit(base_lib.onboard_traditional)
        self._recommend = jax.jit(knn.recommend,
                                  static_argnames=("k_neighbors", "n_rec"))
        self._predict = jax.jit(knn.predict, static_argnames=("k",))

        # Batched query path.  The probe returns everything the host needs
        # to build twin-dedup keys in ONE transfer (top-k sims + neighbour
        # ids + the users' own rating rows); the score call then runs only
        # the deduped rows through the fused scoring kernel and cuts top-n
        # on device, so results come back in one more transfer.  k / n_rec
        # are static; batch shapes are pow2-bucketed by the endpoints.
        self._probe_rec = jax.jit(
            lambda st, users, k: (
                *knn.top_k_neighbors_batch(st, users, k),
                st.ratings[users]),
            static_argnames=("k",))
        self._probe_topk = jax.jit(knn.top_k_neighbors_batch,
                                   static_argnames=("k",))
        self._score_rec = jax.jit(
            lambda st, sims, nbrs, users, n_rec: knn_recommend_topn(
                st.ratings, jnp.maximum(sims, 0.0), nbrs, users, n_rec),
            static_argnames=("n_rec",))
        self._score_pred = jax.jit(
            jax.vmap(knn.predict_from_neighbors, in_axes=(None, 0, 0, 0)))
        self._init_cache = jax.jit(upd_lib.init_cache)
        self._add = jax.jit(upd_lib.add_rating)
        self._healthy = arena_healthy

        # Batched WAL replay: one jitted dispatch per chunk of B records
        # instead of one per record — a lax.scan over the *same* per-step
        # ops the serial path runs, so the replayed state stays
        # bit-identical; only dispatch overhead is amortised.  Twin and
        # traditional records get separate specialised scans: replay
        # compiles exactly the paths the log exercises (a mixed cond body
        # would pay both compiles even for a pure-twin log).  Chunk size
        # is baked into the traced shapes; runs shorter than B fall back
        # to the per-record path.
        s_max, tol = self.s_max, self.tol

        def _twin_chunk(st, Rb, Pb):
            def body(s, inp):
                r0, probes = inp
                s2, res = ts.onboard_twinsearch(
                    s, r0, probes, s_max=s_max, n_base=n_base,
                    k_cap=k_cap, tol=tol)
                return s2, (jnp.asarray(res.found, jnp.bool_),
                            jnp.asarray(res.overflowed, jnp.bool_))

            st, (founds, overs) = jax.lax.scan(body, st, (Rb, Pb))
            return st, founds, overs

        self._replay_twin_chunk = jax.jit(_twin_chunk)

        def _trad_chunk(st, Rb):
            def body(s, r0):
                return base_lib.onboard_traditional(s, r0), None

            st, _ = jax.lax.scan(body, st, Rb)
            return st

        self._replay_trad_chunk = jax.jit(_trad_chunk)

        def _chunk_add(st, cache, users, items, vals):
            def body(carry, inp):
                s, c = carry
                u, i, v = inp
                s, c = upd_lib.add_rating(s, c, u, i, v)
                return (s, c), None

            (st, cache), _ = jax.lax.scan(body, (st, cache),
                                          (users, items, vals))
            return st, cache

        self._replay_add_chunk = jax.jit(_chunk_add)
        # key_{i+1} = split(key_i)[0], n times in one dispatch — the same
        # chain the live path walks one split per twin-search onboard
        self._advance_key = jax.jit(lambda key, m: jax.lax.fori_loop(
            0, m, lambda _, k: jax.random.split(k)[0], key))

    def _reject(self, kind: str, reason: str, payload=None,
                detail: str = "") -> dict:
        self.stats.rejected += 1
        self.quarantine.record(kind, reason, payload, detail)
        return {"status": "rejected", "reason": reason}

    def _crashpoint(self, name: str) -> None:
        """Deterministic crash injection seam (``testing/faults.py``
        installs the hook); a no-op in production."""
        if self._crash_hook is not None:
            self._crash_hook(name)

    # -- degradation ladder -------------------------------------------------

    def _replicas_degraded(self) -> bool:
        return self.replicas is not None and self.replicas.degraded()

    def _set_level(self, level: int) -> None:
        if level == self.level:
            return
        if level > self.level:
            self.stats.degradations += 1
            log.warning("degrading %s -> %s", LEVEL_NAMES[self.level],
                        LEVEL_NAMES[level])
        else:
            self.stats.recoveries += 1
            log.info("recovering %s -> %s", LEVEL_NAMES[self.level],
                     LEVEL_NAMES[level])
        self.level = level
        self._healthy_streak = 0
        if level == LEVEL_SHED:
            self._shed_until = self._clock() + self.shed_cooldown_s

    def _step_down(self) -> None:
        """One recovery step down the ladder.  The ``degraded`` rung is
        owned by replication: stepping out of SHED lands on it while
        redundancy is still lost, and the rung itself is pinned until
        re-replication completes (``_replication_tick`` releases it)."""
        if self.level == LEVEL_SHED:
            self._set_level(LEVEL_DEGRADED if self._replicas_degraded()
                            else LEVEL_TRADITIONAL)
        elif self.level == LEVEL_DEGRADED:
            if not self._replicas_degraded():
                self._set_level(LEVEL_TRADITIONAL)
        else:
            self._set_level(max(LEVEL_TWINSEARCH, self.level - 1))

    def _apply_monitor(self, action: Action) -> None:
        if action is Action.ABORT:
            # A hang-scale latency: shed immediately, don't walk the ladder.
            self._set_level(LEVEL_SHED)
        elif action is Action.CHECKPOINT_AND_SHRINK:
            # Latency verdicts walk twinsearch -> traditional -> shed; the
            # degraded rung is entered only by replica-loss events.
            self._set_level(LEVEL_TRADITIONAL
                            if self.level == LEVEL_TWINSEARCH
                            else LEVEL_SHED)
        else:
            self._healthy_streak += 1
            if (self.level > LEVEL_TWINSEARCH
                    and self._healthy_streak >= self.recover_after):
                self._step_down()

    def _replication_tick(self) -> None:
        """Per-request background replication work: advance re-replication
        by the configured row budget and keep the ladder's ``degraded``
        rung in sync with actual redundancy."""
        if self.replicas is None:
            return
        self.replicas.step_rebuild()
        if self.replicas.degraded():
            if self.level < LEVEL_DEGRADED:
                self._set_level(LEVEL_DEGRADED)
        elif self.level == LEVEL_DEGRADED:
            self._set_level(LEVEL_TRADITIONAL)

    # -- rotation -----------------------------------------------------------

    def _rotate(self) -> None:
        """Grow the arena: compact the write region into a new base (see
        ``core/rotation.py``) and retarget every jitted op at the new
        geometry.  The incremental-update cache keys on the old shapes and
        is dropped; replicas re-mirror the new geometry."""
        old_capacity = self.state.capacity
        t0 = time.perf_counter()
        self.state = rotate_arena(self.state, n_base=self.n_base,
                                  extra=self.k_cap,
                                  headroom=self.rotate_headroom)
        self.state.sim_vals.block_until_ready()
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.n_base = int(self.state.n_active)
        self.k_cap = self.state.capacity - self.n_base
        self._cache = None
        self._build_jits()
        self.stats.rotations += 1
        self.stats.rotation_ms.append(dt_ms)
        # Synchronous rotation: the triggering request stalls for all of it.
        self.stats.rotation_pause_ms.append(dt_ms)
        if self.replicas is not None:
            self.replicas.reset(self.state)
        log.info("arena rotated: capacity %d -> %d (n_base=%d, %.1fms)",
                 old_capacity, self.state.capacity, self.n_base, dt_ms)

    # -- incremental rotation (rotation.budget_rows > 0) --------------------

    def _free_slots(self) -> int:
        return self.state.capacity - int(self.state.n_active)

    def _reserve_slots(self) -> int:
        r = self._rcfg.reserve_slots
        return int(r) if r is not None else max(1, self.k_cap // 4)

    def _start_plan(self) -> None:
        k0 = int(self.state.n_active) - self.n_base
        extra = max(self.k_cap,
                    int(math.ceil(self.rotate_headroom * self.k_cap)))
        self._plan = RotationPlan(self.state, n_base=self.n_base,
                                  extra=extra,
                                  chunk_rows=max(1, self._rcfg.budget_rows))
        log.info("incremental rotation started: n_base=%d burst=%d "
                 "extra=%d", self.n_base, k0, extra)

    def _maintenance_tick(self, budget_rows: int | None = None) -> None:
        """Advance background rotation by one bounded slice and swap when
        the plan completes.  Called at safe points only — between mutating
        ops, never inside one (the in-flight op's closures captured the
        pre-swap state)."""
        if self._rcfg.budget_rows <= 0:
            return
        if self._plan is None:
            if self.k_cap <= 0 or self._free_slots() > self._reserve_slots():
                return
            self._start_plan()
        budget = (int(budget_rows) if budget_rows is not None
                  else self._rcfg.budget_rows)
        if not self._plan.done:
            self._plan.step(self.state, budget)
            self._crashpoint("rotation.step")
        if self._plan.done:
            self._swap_rotation()

    def _drain_during_wait(self, delay_s: float) -> None:
        """Retry-backoff hook: spend otherwise-idle wait time on rotation
        *chunks*.  Never swaps — a retry is mid-onboard and the pending
        ``run`` closure captured the pre-swap state."""
        if (self._plan is not None and not self._plan.done
                and self._rcfg.budget_rows > 0):
            self._plan.step(self.state, self._rcfg.budget_rows)

    def _force_drain(self) -> None:
        """The buffer filled before the plan finished (or before it even
        started): finish the rotation now, synchronously.  Degrades to
        exactly the old stall in the worst case — never worse."""
        if self._plan is None:
            self._start_plan()
        else:
            self.stats.forced_drains += 1
        while not self._plan.done:
            self._plan.step(self.state, max(1, self.n_base))
        self._swap_rotation()

    def _swap_rotation(self) -> None:
        """The atomic swap: log ``rotate_commit``, finalize the plan from
        the live state (bit-identical to ``rotate_arena_frozen``), and
        retarget geometry.  The WAL record carries the frozen boundary so
        recovery replays the swap deterministically at the same point in
        the op stream."""
        plan = self._plan
        old_capacity = self.state.capacity
        t0 = time.perf_counter()
        self._log("rotate_commit", fields={"n_base": plan.n_base,
                                           "n_frozen": plan.n_frozen,
                                           "extra": plan.extra})
        self._crashpoint("rotation.commit_post_wal")
        new_state = plan.finalize(self.state)
        new_state.sim_vals.block_until_ready()
        pause_ms = (time.perf_counter() - t0) * 1e3
        self._install_rotated(new_state, n_base=plan.n_frozen)
        self._plan = None
        self.stats.rotations += 1
        self.stats.rotation_ms.append(plan.elapsed_ms)
        self.stats.rotation_pause_ms.append(pause_ms)
        self.stats.plan_restarts += plan.restarts
        self._crashpoint("rotation.post_swap")
        log.info("arena rotated (incremental): capacity %d -> %d "
                 "(n_base=%d, %.1fms total, %.1fms pause)", old_capacity,
                 self.state.capacity, self.n_base, plan.elapsed_ms,
                 pause_ms)

    def _install_rotated(self, new_state: CFState, *, n_base: int) -> None:
        """Point the server at a rotated arena (live swap or WAL replay)."""
        self.state = new_state
        self.n_base = int(n_base)
        self.k_cap = self.state.capacity - self.n_base
        self._cache = None
        self._build_jits()
        if self.replicas is not None:
            self.replicas.reset(self.state)

    def step_maintenance(self, budget_rows: int | None = None) -> dict:
        """Public maintenance tick: drain up to ``budget_rows`` rows of any
        pending incremental rotation (defaults to the configured
        per-onboard budget).  Wire this into idle-period hooks — e.g. the
        ladder's ``StragglerMonitor`` quiet windows — so rotations finish
        between bursts instead of inside them."""
        self._maintenance_tick(budget_rows)
        plan = self._plan
        return {"active": plan is not None,
                "remaining_rows": plan.remaining_rows if plan else 0,
                "free_slots": self._free_slots()}

    # -- durability: WAL / snapshot / rollback / recovery -------------------

    def _log(self, op: str, fields: dict | None = None,
             arrays: dict | None = None) -> int:
        """Assign the next mutation sequence number and (when a WAL is
        attached and we are not replaying) append the record *before* the
        op is applied — the write-ahead contract."""
        self._seq += 1
        if self.wal is not None and not self._replaying:
            self.wal.append(self._seq, op, fields, arrays)
            self.stats.wal_appends += 1
        return self._seq

    def _take_snapshot(self) -> None:
        self._snapshot = (self.state, self.n_base, self._key, self._seq)
        self.stats.snapshots += 1
        self._since_snapshot = 0
        if self.snapshot_dir is not None:
            checkpoint.save(self.snapshot_dir, self._seq, self.state,
                            extra={"n_base": self.n_base,
                                   "key": np.asarray(self._key).tolist(),
                                   "wal_seq": self._seq},
                            keep_last=self.snapshot_keep)
            if self.wal is not None:
                # The checkpoint subsumes every logged op; drop them.  The
                # incremental dots cache is re-seeded at this boundary so a
                # replayed timeline (which must init it from the restored
                # ratings) stays bit-identical to the live one.
                self.wal.truncate_through(self._seq)
                self._cache = None

    def _rollback(self) -> None:
        state, n_base, key, seq = self._snapshot
        geometry_changed = (state.capacity != self.state.capacity
                            or n_base != self.n_base)
        self.state, self.n_base, self._key = state, n_base, key
        self.k_cap = state.capacity - n_base
        self._seq = seq
        self._cache = None
        self._plan = None          # precomputed against the discarded state
        if geometry_changed:
            self._build_jits()
        if self.wal is not None:
            self.wal.truncate_after(seq)
        if self.replicas is not None:
            self.replicas.reset(self.state)
        self.stats.rollbacks += 1
        self._since_check = 0
        self._since_snapshot = 0
        log.error("arena invariant violated; rolled back to last good "
                  "snapshot (n_active=%d)", int(state.n_active))

    def _recover_state(self) -> None:
        """Restore the newest loadable checkpoint, then replay the WAL
        suffix.  Zero similarity math: the checkpoint is a byte copy and
        replay re-runs only the logged (cheap) maintenance ops."""
        restored = False
        fell_back = False
        if self.snapshot_dir is not None:
            try:
                tree, step, extra = checkpoint.restore(self.snapshot_dir,
                                                       self.state)
            except FileNotFoundError:
                pass
            else:
                self.state = tree
                self.n_base = int(extra.get("n_base", self.n_base))
                self.k_cap = self.state.capacity - self.n_base
                if "key" in extra:
                    self._key = jnp.asarray(extra["key"], jnp.uint32)
                self._seq = int(extra.get("wal_seq", step))
                self._cache = None
                self._build_jits()
                restored = True
                newest = checkpoint.latest_step(self.snapshot_dir)
                fell_back = newest is not None and newest > step
                log.info("restored checkpoint step %d (n_active=%d)",
                         step, int(self.state.n_active))
        if self.wal is not None:
            # Gap checks run on the WAL's *raw* sequence bounds — aborted
            # ops and their compensation records count (records() filters
            # them out of the replay stream, but their seqs were consumed):
            # an aborted prefix is not a missing prefix, and replaying over
            # a genuinely missing one would silently drop committed ops.
            first_raw = self.wal.first_seq
            if not restored:
                if first_raw > 1:
                    raise RuntimeError(
                        f"WAL starts at seq {first_raw} but no checkpoint "
                        f"could be restored — earlier ops were truncated "
                        f"into a checkpoint that is now missing or corrupt")
            elif (first_raw > self._seq + 1
                    or (fell_back and first_raw == 0)):
                # The newest checkpoint was corrupt and the WAL was already
                # truncated through it: the ops between the fallback step
                # and the corrupt one are unrecoverable.  (A crash between
                # checkpoint.save and the WAL truncation leaves the suffix
                # intact — first_seq <= wal_seq + 1 — and recovers fine.)
                raise RuntimeError(
                    f"restored checkpoint is at seq {self._seq} but the WAL "
                    f"{'is empty' if first_raw == 0 else f'starts at seq {first_raw}'}"
                    f" — ops since seq {self._seq} were truncated into a "
                    f"newer checkpoint that is corrupt; refusing to replay "
                    f"over the gap")
            self._replay(self.wal.records(after_seq=self._seq))
            # Resume numbering past the raw WAL tail: an aborted tail op's
            # seq (and its abort record's) never replays, but reissuing it
            # would make records() drop the next committed op as aborted on
            # a later recovery.
            self._seq = max(self._seq, self.wal.last_seq)

    def _replay(self, records) -> None:
        """Replay a WAL suffix.  With ``wal.replay_batch > 1`` maximal
        contiguous runs of same-op, same-path ``onboard``/``add_rating``
        records are driven through one specialised jitted scan per full
        chunk (same per-step ops — bit-identical state, one dispatch
        instead of B); short runs and run tails take the per-record path.
        ``rotate`` / ``rotate_commit`` records break runs: they change
        arena geometry."""
        records = list(records)
        B = max(1, int(self._wcfg.replay_batch))
        self._replaying = True
        try:
            i = 0
            while i < len(records):
                rec = records[i]
                if B > 1 and rec.op in ("onboard", "add_rating"):
                    j = i
                    while j < len(records) and records[j].op == rec.op:
                        j += 1
                    run = records[i:j]
                    if rec.op == "onboard":
                        self._replay_onboard_run(run, B)
                    else:
                        self._replay_add_rating_run(run, B)
                    i = j
                    continue
                self._seq = rec.seq
                if rec.op == "rotate":
                    self._rotate()
                elif rec.op == "rotate_commit":
                    self._replay_rotate_commit(rec)
                elif rec.op == "onboard":
                    self._replay_onboard(rec)
                elif rec.op == "add_rating":
                    self._replay_add_rating(rec)
                else:
                    log.warning("unknown WAL op %r at seq %d skipped",
                                rec.op, rec.seq)
                self.stats.wal_replayed += 1
                i += 1
        finally:
            self._replaying = False

    def _replay_rotate_commit(self, rec) -> None:
        """Deterministic replay of an incremental rotation's atomic swap:
        the record pins the frozen boundary and growth, so
        ``rotate_arena_frozen`` reproduces the swapped arena bit-exactly
        at the same point in the op stream."""
        f = rec.fields
        new_state = rotate_arena_frozen(
            self.state, n_base=int(f["n_base"]),
            n_frozen=int(f["n_frozen"]), extra=int(f["extra"]))
        new_state.sim_vals.block_until_ready()
        self._install_rotated(new_state, n_base=int(f["n_frozen"]))
        self.stats.rotations += 1

    def _replay_onboard_run(self, run, B: int) -> None:
        # maximal same-path sub-runs, so each chunk hits one specialised jit
        j = 0
        while j < len(run):
            tw = bool(run[j].fields.get("use_twin", False))
            k = j + 1
            while (k < len(run)
                   and bool(run[k].fields.get("use_twin", False)) == tw):
                k += 1
            self._replay_uniform_run(run[j:k], B, use_twin=tw)
            j = k

    def _replay_uniform_run(self, run, B: int, *, use_twin: bool) -> None:
        i = 0
        if use_twin and any(r.arrays["probes"].shape != (self.c,)
                            for r in run):
            i = len(run)             # foreign probe shape: replay serially
        while len(run) - i >= B:
            chunk = run[i:i + B]
            Rb = jnp.asarray(np.stack([r.arrays["ratings"]
                                       .astype(np.float32) for r in chunk]))
            if use_twin:
                Pb = jnp.asarray(np.stack([r.arrays["probes"]
                                           for r in chunk]).astype(np.int32))
                # Advance the PRNG stream exactly as the live path did:
                # one split per twin-search op (probes still come from
                # the records — they are authoritative).
                self._key = self._advance_key(self._key, B)
                st, founds, overs = self._replay_twin_chunk(
                    self.state, Rb, Pb)
                n_found = int(np.asarray(founds).sum())
                self.stats.twin_hits += n_found
                self.stats.fallbacks += B - n_found
                self.stats.overflows += int(np.asarray(overs).sum())
            else:
                st = self._replay_trad_chunk(self.state, Rb)
                self.stats.fallbacks += B
            st.n_active.block_until_ready()
            self.state = st
            self.stats.onboarded += B
            self.stats.wal_replayed += B
            self._seq = chunk[-1].seq
            i += B
        for r in run[i:]:
            self._seq = r.seq
            self._replay_onboard(r)
            self.stats.wal_replayed += 1

    def _replay_add_rating_run(self, run, B: int) -> None:
        i = 0
        if len(run) >= B and self._cache is None:
            # The serial path seeds the cache lazily on the first add;
            # seed it from the same ratings here so the scan sees an
            # identical carry.
            self._cache = self._init_cache(self.state.ratings)
        while len(run) - i >= B:
            chunk = run[i:i + B]
            users = np.asarray([int(r.fields["user"]) for r in chunk],
                               np.int32)
            items = np.asarray([int(r.fields["item"]) for r in chunk],
                               np.int32)
            vals = np.asarray([float(r.fields["rating"]) for r in chunk],
                              np.float32)
            st, cache = self._replay_add_chunk(
                self.state, self._cache, jnp.asarray(users),
                jnp.asarray(items), jnp.asarray(vals))
            st.n_active.block_until_ready()
            self.state, self._cache = st, cache
            self.stats.wal_replayed += B
            self._seq = chunk[-1].seq
            i += B
        for r in run[i:]:
            self._seq = r.seq
            self._replay_add_rating(r)
            self.stats.wal_replayed += 1

    def _replay_onboard(self, rec) -> None:
        r0 = jnp.asarray(rec.arrays["ratings"].astype(np.float32))
        use_twin = bool(rec.fields.get("use_twin", False))
        if use_twin:
            # Advance the PRNG stream exactly as the live path did; the
            # recorded probes equal the re-derived ones, but the record is
            # authoritative (recovery works even from a foreign key state).
            self._key, _ = jax.random.split(self._key)
            probes = jnp.asarray(rec.arrays["probes"])
            new_state, res = self._onboard(self.state, r0, probes)
            found, overflowed = bool(res.found), bool(res.overflowed)
        else:
            new_state = self._onboard_trad(self.state, r0)
            found = overflowed = False
        new_state.n_active.block_until_ready()
        self._commit_onboard(new_state, found, overflowed)

    def _replay_add_rating(self, rec) -> None:
        f = rec.fields
        self._apply_add_rating(int(f["user"]), int(f["item"]),
                               float(f["rating"]))

    # -- health check + snapshot cadence ------------------------------------

    def _state_ok(self) -> bool:
        """Verify the arena invariant; heal poisoned rows from replicas
        (exact, similarity-free) when possible, roll back to the last good
        snapshot otherwise.  False iff a rollback happened."""
        if bool(self._healthy(self.state.sim_vals, self.state.ratings,
                              self.state.norms, self.state.n_active)):
            return True
        if self.replicas is not None:
            fixed, rows = self.replicas.repair(self.state)
            if fixed is not None and bool(self._healthy(
                    fixed.sim_vals, fixed.ratings, fixed.norms,
                    fixed.n_active)):
                self.state = fixed
                self._cache = None
                self.stats.repairs += 1
                log.warning("healed %d poisoned arena rows from replicas",
                            len(rows))
                return True
        self._rollback()
        return False

    def _check_and_snapshot(self) -> bool:
        """Periodic poison detection + snapshot cadence.  Returns False if
        the current state failed the invariant and was rolled back (a
        replica-healed state counts as healthy)."""
        self._since_check += 1
        self._since_snapshot += 1
        if self._since_check >= self.check_every:
            self._since_check = 0
            if self.replicas is not None:
                self.replicas.sweep()
            if not self._state_ok():
                return False
        if self._since_snapshot >= self.snapshot_every:
            # Never snapshot unverified state: a snapshot of a poisoned
            # arena would poison every future rollback.
            if bool(self._healthy(self.state.sim_vals, self.state.ratings,
                                  self.state.norms, self.state.n_active)):
                self._take_snapshot()
        return True

    # -- onboarding ---------------------------------------------------------

    def _commit_onboard(self, new_state: CFState, found: bool,
                        overflowed: bool) -> None:
        self.state = new_state
        self.stats.onboarded += 1
        self.stats.twin_hits += found
        self.stats.fallbacks += not found
        self.stats.overflows += overflowed
        if self.replicas is not None:
            self.replicas.apply_rows([int(new_state.n_active) - 1],
                                     new_state)

    def onboard_user(self, ratings: np.ndarray, *,
                     use_twinsearch: bool = True) -> OnboardResult:
        reason = guard.validate_ratings_vector(
            ratings, n_items=self.state.n_items,
            rating_range=self.rating_range)
        if reason is not None:
            self._reject("onboard", reason, ratings)
            return OnboardResult(status="rejected", reason=reason,
                                 rung=LEVEL_NAMES[self.level])

        self._replication_tick()
        if self.level == LEVEL_SHED:
            if self._clock() < self._shed_until:
                self.stats.shed += 1
                if self._lcfg.drain_on_shed:
                    # Backpressure time is free maintenance time.
                    self._maintenance_tick()
                return OnboardResult(
                    status="shed", rung=LEVEL_NAMES[self.level],
                    retry_after_s=self._shed_until - self._clock())
            # Cooldown expired: probe the cheaper build path again.
            self._set_level(LEVEL_DEGRADED if self._replicas_degraded()
                            else LEVEL_TRADITIONAL)

        # Background rotation tick: a safe point (no op in flight).
        self._maintenance_tick()

        self._crashpoint("onboard.pre_wal")
        rotated = False
        if int(self.state.n_active) >= self.state.capacity:
            rotated = True
            if self._rcfg.budget_rows > 0:
                # The plan didn't finish (or start) in time: drain it now.
                self._force_drain()
            else:
                self._log("rotate")
                self._crashpoint("rotate.post_wal")
                self._rotate()

        r0_np = np.asarray(ratings, dtype=np.float32)
        r0 = jnp.asarray(r0_np)
        use_twin = use_twinsearch and self.level == LEVEL_TWINSEARCH
        if use_twin:
            self._key, sub = jax.random.split(self._key)
            probes = jax.random.randint(sub, (self.c,), 0, self.n_base)

            def run():
                new_state, res = self._onboard(self.state, r0, probes)
                new_state.n_active.block_until_ready()
                return new_state, bool(res.found), bool(res.overflowed)
        else:
            probes = None

            def run():
                new_state = self._onboard_trad(self.state, r0)
                new_state.n_active.block_until_ready()
                return new_state, False, False

        seq = self._log(
            "onboard", fields={"use_twin": bool(use_twin)},
            arrays={"ratings": r0_np,
                    "probes": (np.asarray(probes) if probes is not None
                               else np.empty((0,), np.int32))})
        self._crashpoint("onboard.post_wal")

        self.monitor.step_started()
        t0 = time.perf_counter()
        try:
            (new_state, found, overflowed), retries = guard.call_with_retry(
                run, self.retry)
        except Exception as e:          # noqa: BLE001 — contract: no raise
            self.monitor.step_finished()
            self.stats.errors += 1
            # Compensate the write-ahead record: the op never applied, so
            # replay must skip it.
            self._log("abort", fields={"target": seq})
            self.quarantine.record("onboard", guard.R_ERROR, ratings,
                                   detail=repr(e))
            log.error("onboard failed after retries: %r", e)
            return OnboardResult(status="error", reason=guard.R_ERROR,
                                 rung=LEVEL_NAMES[self.level],
                                 rotated=rotated, seq=seq, detail=repr(e))
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._apply_monitor(self.monitor.step_finished())

        self.stats.retries += retries
        self._commit_onboard(new_state, found, overflowed)
        self.stats.onboard_ms.append(dt_ms)
        self._crashpoint("onboard.post_commit")

        if not self._check_and_snapshot():
            return OnboardResult(status="rolled_back", latency_ms=dt_ms,
                                 rung=LEVEL_NAMES[self.level],
                                 rotated=rotated, seq=seq)
        uid = int(self.state.n_active) - 1
        return OnboardResult(user_id=uid, status="ok", twin_found=found,
                             latency_ms=dt_ms, rung=LEVEL_NAMES[self.level],
                             rotated=rotated, seq=seq)

    def onboard_batch(self, ratings_batch, *,
                      use_twinsearch: bool = True) -> list[OnboardResult]:
        """Onboard a sequence of users under one WAL group commit: the
        batch's appends coalesce into a single write+fsync
        (``wal.group_commit``), trading per-record durability for
        per-batch durability — a crash mid-batch replays to the last
        *flushed* batch boundary, never to a torn prefix.  Results are
        per-user ``OnboardResult``s, same contract as ``onboard_user``."""
        ctx = (self.wal.batch()
               if self.wal is not None and self._wcfg.group_commit
               else contextlib.nullcontext())
        with ctx:
            return [self.onboard_user(r, use_twinsearch=use_twinsearch)
                    for r in ratings_batch]

    # -- queries ------------------------------------------------------------

    def _query_k(self, k_neighbors: int) -> int:
        """Degradation-ladder interaction for reads: the shed rung serves
        queries at a reduced neighbour count instead of refusing them."""
        if self.level == LEVEL_SHED:
            return max(1, int(k_neighbors) // SHED_QUERY_K_DIV)
        return int(k_neighbors)

    def _pre_query(self) -> None:
        if self.replicas is not None:
            # Failover read: heal any poisoned rows from replicas before
            # answering, so a lost shard degrades durability, not answers.
            self._replication_tick()
            self._state_ok()

    def _note_query_batch(self, n_valid: int, n_unique: int, savings: float,
                          dt_ms: float, degraded: bool) -> None:
        self.stats.query_batches += 1
        self.stats.queries += n_valid
        self.stats.query_unique += n_unique
        self.stats.query_ms.append(dt_ms)
        self.stats.query_dedup_savings.append(savings)
        if degraded:
            self.stats.query_degraded += n_valid

    @staticmethod
    def _pad_bucket(arr: np.ndarray) -> np.ndarray:
        """Pad axis 0 to the pow2 bucket by repeating the last row — a
        valid, already-requested row, so the padded program computes
        nothing undefined and the host slices the extras away."""
        n = arr.shape[0]
        pad = _bucket_pow2(n) - n
        if pad == 0:
            return arr
        return np.concatenate([arr, np.repeat(arr[-1:], pad, axis=0)])

    def recommend_batch(self, users, n: int = 10, k_neighbors: int = 20
                        ) -> list[list[tuple[int, float]]]:
        """Top-``n`` recommendations for a batch of users in one device
        dispatch.  Per-row guard: an invalid user id is quarantined and
        its slot answers ``[]`` while the rest of the batch is served.
        Twin dedup: rows whose (top-k sims, neighbour ids, own-ratings)
        keys are bitwise identical are scored once and fanned out."""
        users = list(users)
        results: list[list[tuple[int, float]]] = [[] for _ in users]
        valid = [i for i, u in enumerate(users)
                 if not (guard.validate_user_id(u, int(self.state.n_active))
                         and self._reject("recommend", guard.R_USER_ID, u))]
        if not valid:
            return results
        self._pre_query()
        k_eff = self._query_k(k_neighbors)
        t0 = time.perf_counter()

        uvec = np.asarray([int(users[i]) for i in valid], np.int32)
        sims, nbrs, rows = jax.device_get(self._probe_rec(
            self.state, jnp.asarray(self._pad_bucket(uvec)), k_eff))
        B = len(uvec)
        sims, nbrs, rows = sims[:B], nbrs[:B], rows[:B]

        # Twin dedup (probe -> exact verify): the scoring kernel is a
        # deterministic function of exactly (sims, nbrs, own row), so
        # bitwise-equal keys provably share scores.
        keys = np.concatenate([sims.view(np.uint32), nbrs.view(np.uint32),
                               rows.view(np.uint32)], axis=1)
        plan = dedup_rows(keys)
        sel = self._pad_bucket(plan.unique_rows)
        scores, items = jax.device_get(self._score_rec(
            self.state, jnp.asarray(sims[sel]), jnp.asarray(nbrs[sel]),
            jnp.asarray(uvec[sel]), n))

        dt_ms = (time.perf_counter() - t0) * 1e3
        for pos, i in enumerate(valid):
            u = int(plan.scatter[pos])           # fan_out, zipped on host
            results[i] = [(int(it), float(s))
                          for s, it in zip(scores[u], items[u])]
        self._note_query_batch(B, plan.n_unique, plan.savings, dt_ms,
                               degraded=k_eff != int(k_neighbors))
        return results

    def predict_batch(self, users, items, k: int = 20) -> list[float]:
        """kNN rating predictions for B (user, item) pairs in one device
        dispatch; invalid rows are quarantined and answer 0.0.  Twin
        dedup keys on (top-k sims, neighbour ids, item)."""
        users, items = list(users), list(items)
        assert len(users) == len(items), (len(users), len(items))
        results = [0.0] * len(users)
        valid = []
        for i, (u, it) in enumerate(zip(users, items)):
            if guard.validate_user_id(u, int(self.state.n_active)):
                self._reject("predict", guard.R_USER_ID, u)
            elif guard.validate_item_id(it, self.state.n_items):
                self._reject("predict", guard.R_ITEM_ID, it)
            else:
                valid.append(i)
        if not valid:
            return results
        self._pre_query()
        k_eff = self._query_k(k)
        t0 = time.perf_counter()

        uvec = np.asarray([int(users[i]) for i in valid], np.int32)
        ivec = np.asarray([int(items[i]) for i in valid], np.int32)
        sims, nbrs = jax.device_get(self._probe_topk(
            self.state, jnp.asarray(self._pad_bucket(uvec)), k_eff))
        B = len(uvec)
        sims, nbrs = sims[:B], nbrs[:B]

        keys = np.concatenate([sims.view(np.uint32), nbrs.view(np.uint32),
                               ivec.reshape(-1, 1).view(np.uint32)], axis=1)
        plan = dedup_rows(keys)
        sel = self._pad_bucket(plan.unique_rows)
        preds = jax.device_get(self._score_pred(
            self.state, jnp.asarray(sims[sel]), jnp.asarray(nbrs[sel]),
            jnp.asarray(ivec[sel])))

        dt_ms = (time.perf_counter() - t0) * 1e3
        for pos, i in enumerate(valid):
            results[i] = float(preds[int(plan.scatter[pos])])
        self._note_query_batch(B, plan.n_unique, plan.savings, dt_ms,
                               degraded=k_eff != int(k))
        return results

    def recommend(self, user: int, n: int = 10,
                  k_neighbors: int = 20) -> list[tuple[int, float]]:
        """Thin B=1 wrapper over ``recommend_batch`` (one device
        dispatch, one host transfer — no per-element sync)."""
        return self.recommend_batch([user], n=n, k_neighbors=k_neighbors)[0]

    def predict(self, user: int, item: int, k: int = 20) -> float:
        """Thin B=1 wrapper over ``predict_batch``."""
        return self.predict_batch([user], [item], k=k)[0]

    # -- maintenance --------------------------------------------------------

    def _apply_add_rating(self, user: int, item: int,
                          rating: float) -> None:
        if self._cache is None:
            self._cache = self._init_cache(self.state.ratings)
        self.state, self._cache = self._add(
            self.state, self._cache, jnp.int32(user), jnp.int32(item),
            jnp.float32(rating))
        if self.replicas is not None:
            self.replicas.apply_rows([user], self.state)
        if self._plan is not None:
            # A refreshed row may invalidate part of the rotation plan's
            # precompute; the plan re-merges it before the swap.
            self._plan.note_write(int(user))

    def add_rating(self, user: int, item: int, rating: float) -> bool:
        """Returns True iff the update was applied (False = quarantined)."""
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("add_rating", guard.R_USER_ID, user)
            return False
        if guard.validate_item_id(item, self.state.n_items):
            self._reject("add_rating", guard.R_ITEM_ID, item)
            return False
        reason = guard.validate_rating_value(rating, self.rating_range)
        if reason is not None:
            self._reject("add_rating", reason, rating)
            return False
        self._replication_tick()
        self._crashpoint("add_rating.pre_wal")
        self._log("add_rating", fields={"user": int(user), "item": int(item),
                                        "rating": float(rating)})
        self._crashpoint("add_rating.post_wal")
        self._apply_add_rating(int(user), int(item), float(rating))
        self._crashpoint("add_rating.post_commit")
        return True
