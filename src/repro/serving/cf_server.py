"""Neighbourhood-CF recommendation server with the paper's TwinSearch
new-user onboarding fast path, hardened for bursty production traffic.

Request surface (what a real deployment fronts with an RPC layer):

  * ``onboard_user(ratings)``   — TwinSearch -> copy, or traditional build
                                  fallback; returns the new user id + info.
  * ``recommend(user, n)``      — top-n unseen items via kNN scores.
  * ``predict(user, item)``     — kNN weighted-average rating.
  * ``add_rating(user, item, r)``— incremental (Papagelis-style) update of
                                  the affected similarity row.

Resilience contract: **no public entrypoint raises to the caller.**

  * Malformed payloads (NaN/Inf, wrong shape/dtype, out-of-range, bogus
    ids) are refused by ``serving/guard.py`` before touching any jitted
    kernel and land in a bounded quarantine; the caller gets a structured
    refusal (``status="rejected"``).
  * Capacity exhaustion triggers **arena rotation**
    (``core/rotation.py``): the write region compacts into a larger base
    arena via PR 1's fused k-way merge — onboarding continues past the
    original ``capacity_extra`` indefinitely.
  * Onboard latencies feed a ``StragglerMonitor`` (``training/elastic.py``)
    driving a **degradation ladder**: twinsearch -> traditional-build ->
    shed-with-backpressure, stepping down on straggler verdicts and back
    up after a healthy streak (shed expires on a cooldown clock).  Every
    transition is counted in ``ServerStats``.
  * The jitted onboard call runs under retry-with-exponential-backoff and
    a deadline (transient executor faults); a call that still fails is
    quarantined, not raised.
  * Periodic atomic **snapshots** (in-memory always; on disk via
    ``training/checkpoint.py`` when ``snapshot_dir`` is set) pair with a
    cheap NaN/ordering invariant check (``kernels/verify_rows``): a
    poisoned arena — bit-flips, simulated shard loss — is detected within
    ``check_every`` onboards and rolled back to the last good snapshot.

State is the fixed-capacity ``CFState`` (jit-friendly); all mutating ops
are jitted once per arena shape and reused.  ``stats`` tracks twin hits /
fallbacks / latencies / resilience transitions — the serving-side
visibility the benchmarks read.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (CFState, build_state, knn, set0_cap)
from repro.core import baseline as base_lib
from repro.core import twinsearch as ts
from repro.core import update as upd_lib
from repro.core.rotation import rotate_arena
from repro.kernels.verify_rows.ops import arena_healthy
from repro.serving import guard
from repro.training import checkpoint
from repro.training.elastic import Action, StragglerMonitor

log = logging.getLogger(__name__)

# Degradation ladder levels (ascending = more degraded).
LEVEL_TWINSEARCH = 0
LEVEL_TRADITIONAL = 1
LEVEL_SHED = 2
LEVEL_NAMES = {LEVEL_TWINSEARCH: "twinsearch",
               LEVEL_TRADITIONAL: "traditional",
               LEVEL_SHED: "shed"}


@dataclass
class ServerStats:
    onboarded: int = 0
    twin_hits: int = 0
    fallbacks: int = 0
    overflows: int = 0
    rejected: int = 0
    shed: int = 0
    retries: int = 0
    errors: int = 0
    rotations: int = 0
    snapshots: int = 0
    rollbacks: int = 0
    degradations: int = 0
    recoveries: int = 0
    latency_window: int = 1024
    onboard_ms: deque = field(init=False)

    def __post_init__(self) -> None:
        # Fixed-size ring buffer: sustained traffic must not grow host
        # memory; summary() percentiles are over the trailing window.
        self.onboard_ms = deque(maxlen=self.latency_window)

    def summary(self) -> dict:
        ms = sorted(self.onboard_ms) or [0.0]
        return {
            "onboarded": self.onboarded,
            "twin_hits": self.twin_hits,
            "fallbacks": self.fallbacks,
            "overflows": self.overflows,
            "rejected": self.rejected,
            "shed": self.shed,
            "retries": self.retries,
            "errors": self.errors,
            "rotations": self.rotations,
            "snapshots": self.snapshots,
            "rollbacks": self.rollbacks,
            "degradations": self.degradations,
            "recoveries": self.recoveries,
            "onboard_p50_ms": ms[len(ms) // 2],
            "onboard_p99_ms": ms[min(len(ms) - 1, int(len(ms) * 0.99))],
        }


class CFServer:
    def __init__(self, ratings: np.ndarray, *, capacity_extra: int = 64,
                 c_probes: int = 8, sim_tol: float = 1e-6,
                 measure: str = "cosine", seed: int = 0,
                 rating_range: tuple[float, float] = (1.0, 5.0),
                 quarantine_capacity: int = 256,
                 latency_window: int = 1024,
                 retry: guard.RetryPolicy | None = None,
                 monitor: StragglerMonitor | None = None,
                 recover_after: int = 32,
                 shed_cooldown_s: float = 1.0,
                 snapshot_every: int = 64,
                 snapshot_dir: str | None = None,
                 snapshot_keep: int = 3,
                 check_every: int = 8):
        self.n_base = int(ratings.shape[0])
        self.k_cap = int(capacity_extra)
        self.c = c_probes
        self.tol = sim_tol
        self.rating_range = (float(rating_range[0]), float(rating_range[1]))
        self.state: CFState = jax.jit(
            lambda R: build_state(R, capacity_extra=capacity_extra,
                                  measure=measure))(jnp.asarray(
                                      ratings, jnp.float32))
        self._key = jax.random.PRNGKey(seed)
        self.stats = ServerStats(latency_window=latency_window)
        self.quarantine = guard.Quarantine(capacity=quarantine_capacity)

        # Degradation ladder + retry machinery.  The monitor's clock is the
        # server's time source for shed cooldowns too, so fault-injection
        # tests drive the whole ladder in virtual time.
        self.retry = retry or guard.RetryPolicy()
        self.monitor = monitor or StragglerMonitor(
            window=64, straggler_ratio=4.0, hang_timeout_s=30.0,
            consecutive_to_shrink=3)
        self._clock = self.monitor.clock
        self.level = LEVEL_TWINSEARCH
        self.recover_after = int(recover_after)
        self.shed_cooldown_s = float(shed_cooldown_s)
        self._healthy_streak = 0
        self._shed_until = 0.0

        # Snapshot / rollback machinery.
        self.snapshot_every = int(snapshot_every)
        self.snapshot_dir = snapshot_dir
        self.snapshot_keep = int(snapshot_keep)
        self.check_every = int(check_every)
        self._since_snapshot = 0
        self._since_check = 0

        # All jitted entrypoints are constructed eagerly (construction is
        # free — tracing happens on first call) so a first-call exception
        # can never leave the server half-initialised; the update cache is
        # still *computed* lazily (it is O(N^2) memory).
        self._cache = None
        self._build_jits()
        self._snapshot = None
        self._take_snapshot()            # the construction-time good state

    # -- internal machinery -------------------------------------------------

    def _build_jits(self) -> None:
        """(Re)wrap the jitted ops for the *current* arena geometry.
        Called at construction and after every rotation/rollback — the
        closures capture ``n_base``/``s_max``/``k_cap``, which rotation
        changes."""
        self.s_max = set0_cap(self.n_base)
        n_base, k_cap = self.n_base, self.k_cap
        self._onboard = jax.jit(lambda st, r0, probes: ts.onboard_twinsearch(
            st, r0, probes, s_max=self.s_max, n_base=n_base,
            k_cap=k_cap, tol=self.tol))
        self._onboard_trad = jax.jit(base_lib.onboard_traditional)
        self._recommend = jax.jit(knn.recommend,
                                  static_argnames=("k_neighbors", "n_rec"))
        self._predict = jax.jit(knn.predict, static_argnames=("k",))
        self._init_cache = jax.jit(upd_lib.init_cache)
        self._add = jax.jit(upd_lib.add_rating)
        self._healthy = arena_healthy

    def _reject(self, kind: str, reason: str, payload=None,
                detail: str = "") -> dict:
        self.stats.rejected += 1
        self.quarantine.record(kind, reason, payload, detail)
        return {"status": "rejected", "reason": reason}

    def _set_level(self, level: int) -> None:
        if level == self.level:
            return
        if level > self.level:
            self.stats.degradations += 1
            log.warning("degrading %s -> %s", LEVEL_NAMES[self.level],
                        LEVEL_NAMES[level])
        else:
            self.stats.recoveries += 1
            log.info("recovering %s -> %s", LEVEL_NAMES[self.level],
                     LEVEL_NAMES[level])
        self.level = level
        self._healthy_streak = 0
        if level == LEVEL_SHED:
            self._shed_until = self._clock() + self.shed_cooldown_s

    def _apply_monitor(self, action: Action) -> None:
        if action is Action.ABORT:
            # A hang-scale latency: shed immediately, don't walk the ladder.
            self._set_level(LEVEL_SHED)
        elif action is Action.CHECKPOINT_AND_SHRINK:
            self._set_level(min(self.level + 1, LEVEL_SHED))
        else:
            self._healthy_streak += 1
            if (self.level > LEVEL_TWINSEARCH
                    and self._healthy_streak >= self.recover_after):
                self._set_level(self.level - 1)

    def _rotate(self) -> None:
        """Grow the arena: compact the write region into a new base (see
        ``core/rotation.py``) and retarget every jitted op at the new
        geometry.  The incremental-update cache keys on the old shapes and
        is dropped."""
        old_capacity = self.state.capacity
        self.state = rotate_arena(self.state, n_base=self.n_base,
                                  extra=self.k_cap)
        self.n_base = int(self.state.n_active)
        self._cache = None
        self._build_jits()
        self.stats.rotations += 1
        log.info("arena rotated: capacity %d -> %d (n_base=%d)",
                 old_capacity, self.state.capacity, self.n_base)

    def _take_snapshot(self) -> None:
        self._snapshot = (self.state, self.n_base)
        self.stats.snapshots += 1
        self._since_snapshot = 0
        if self.snapshot_dir is not None:
            checkpoint.save(self.snapshot_dir, self.stats.onboarded,
                            self.state,
                            extra={"n_base": self.n_base},
                            keep_last=self.snapshot_keep)

    def _rollback(self) -> None:
        state, n_base = self._snapshot
        geometry_changed = (state.capacity != self.state.capacity
                            or n_base != self.n_base)
        self.state, self.n_base = state, n_base
        self._cache = None
        if geometry_changed:
            self._build_jits()
        self.stats.rollbacks += 1
        self._since_check = 0
        self._since_snapshot = 0
        log.error("arena invariant violated; rolled back to last good "
                  "snapshot (n_active=%d)", int(state.n_active))

    def _check_and_snapshot(self) -> bool:
        """Periodic poison detection + snapshot cadence.  Returns False if
        the current state failed the invariant and was rolled back."""
        self._since_check += 1
        self._since_snapshot += 1
        if self._since_check >= self.check_every:
            self._since_check = 0
            if not bool(self._healthy(self.state.sim_vals,
                                      self.state.ratings, self.state.norms,
                                      self.state.n_active)):
                self._rollback()
                return False
        if self._since_snapshot >= self.snapshot_every:
            # Never snapshot unverified state: a snapshot of a poisoned
            # arena would poison every future rollback.
            if bool(self._healthy(self.state.sim_vals, self.state.ratings,
                                  self.state.norms, self.state.n_active)):
                self._take_snapshot()
        return True

    # -- onboarding ---------------------------------------------------------

    def onboard_user(self, ratings: np.ndarray, *,
                     use_twinsearch: bool = True) -> tuple[int, dict]:
        reason = guard.validate_ratings_vector(
            ratings, n_items=self.state.n_items,
            rating_range=self.rating_range)
        if reason is not None:
            return -1, {**self._reject("onboard", reason, ratings),
                        "twin_found": False}

        if self.level == LEVEL_SHED:
            if self._clock() < self._shed_until:
                self.stats.shed += 1
                return -1, {"status": "shed", "twin_found": False,
                            "retry_after_s": self._shed_until - self._clock()}
            # Cooldown expired: probe the cheaper build path again.
            self._set_level(LEVEL_TRADITIONAL)

        if int(self.state.n_active) >= self.state.capacity:
            self._rotate()

        r0 = jnp.asarray(np.asarray(ratings, dtype=np.float32))
        use_twin = use_twinsearch and self.level == LEVEL_TWINSEARCH
        if use_twin:
            self._key, sub = jax.random.split(self._key)
            probes = jax.random.randint(sub, (self.c,), 0, self.n_base)

            def run():
                new_state, res = self._onboard(self.state, r0, probes)
                new_state.n_active.block_until_ready()
                return new_state, bool(res.found), bool(res.overflowed)
        else:
            def run():
                new_state = self._onboard_trad(self.state, r0)
                new_state.n_active.block_until_ready()
                return new_state, False, False

        self.monitor.step_started()
        t0 = time.perf_counter()
        try:
            (new_state, found, overflowed), retries = guard.call_with_retry(
                run, self.retry)
        except Exception as e:          # noqa: BLE001 — contract: no raise
            self.monitor.step_finished()
            self.stats.errors += 1
            self.quarantine.record("onboard", guard.R_ERROR, ratings,
                                   detail=repr(e))
            log.error("onboard failed after retries: %r", e)
            return -1, {"status": "error", "reason": guard.R_ERROR,
                        "twin_found": False, "detail": repr(e)}
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._apply_monitor(self.monitor.step_finished())

        self.stats.retries += retries
        self.stats.twin_hits += found
        self.stats.fallbacks += not found
        self.stats.overflows += overflowed
        self.state = new_state
        self.stats.onboarded += 1
        self.stats.onboard_ms.append(dt_ms)

        if not self._check_and_snapshot():
            return -1, {"status": "rolled_back", "twin_found": False,
                        "ms": dt_ms}
        uid = int(self.state.n_active) - 1
        return uid, {"status": "ok", "twin_found": found, "ms": dt_ms,
                     "level": LEVEL_NAMES[self.level]}

    # -- queries ------------------------------------------------------------

    def recommend(self, user: int, n: int = 10,
                  k_neighbors: int = 20) -> list[tuple[int, float]]:
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("recommend", guard.R_USER_ID, user)
            return []
        scores, items = self._recommend(self.state, jnp.int32(user),
                                        k_neighbors=k_neighbors, n_rec=n)
        return [(int(i), float(s)) for s, i in zip(scores, items)]

    def predict(self, user: int, item: int, k: int = 20) -> float:
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("predict", guard.R_USER_ID, user)
            return 0.0
        if guard.validate_item_id(item, self.state.n_items):
            self._reject("predict", guard.R_ITEM_ID, item)
            return 0.0
        return float(self._predict(self.state, jnp.int32(user),
                                   jnp.int32(item), k=k))

    # -- maintenance --------------------------------------------------------

    def add_rating(self, user: int, item: int, rating: float) -> bool:
        """Returns True iff the update was applied (False = quarantined)."""
        if guard.validate_user_id(user, int(self.state.n_active)):
            self._reject("add_rating", guard.R_USER_ID, user)
            return False
        if guard.validate_item_id(item, self.state.n_items):
            self._reject("add_rating", guard.R_ITEM_ID, item)
            return False
        reason = guard.validate_rating_value(rating, self.rating_range)
        if reason is not None:
            self._reject("add_rating", reason, rating)
            return False
        if self._cache is None:
            self._cache = self._init_cache(self.state.ratings)
        self.state, self._cache = self._add(
            self.state, self._cache, jnp.int32(user), jnp.int32(item),
            jnp.float32(rating))
        return True
