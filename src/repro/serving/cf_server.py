"""Neighbourhood-CF recommendation server with the paper's TwinSearch
new-user onboarding fast path.

Request surface (what a real deployment fronts with an RPC layer):

  * ``onboard_user(ratings)``   — TwinSearch -> copy, or traditional build
                                  fallback; returns the new user id + stats.
  * ``recommend(user, n)``      — top-n unseen items via kNN scores.
  * ``predict(user, item)``     — kNN weighted-average rating.
  * ``add_rating(user, item, r)``— incremental (Papagelis-style) update of
                                  the affected similarity row.

State is the fixed-capacity ``CFState`` (jit-friendly); all mutating ops
are jitted once and reused.  ``stats`` tracks twin hits / fallbacks /
latencies — the serving-side visibility the benchmarks read.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (CFState, build_state, knn, set0_cap)
from repro.core import baseline as base_lib
from repro.core import twinsearch as ts
from repro.core import update as upd_lib


@dataclass
class ServerStats:
    onboarded: int = 0
    twin_hits: int = 0
    fallbacks: int = 0
    overflows: int = 0
    onboard_ms: list[float] = field(default_factory=list)

    def summary(self) -> dict:
        ms = sorted(self.onboard_ms) or [0.0]
        return {
            "onboarded": self.onboarded,
            "twin_hits": self.twin_hits,
            "fallbacks": self.fallbacks,
            "overflows": self.overflows,
            "onboard_p50_ms": ms[len(ms) // 2],
            "onboard_p99_ms": ms[min(len(ms) - 1, int(len(ms) * 0.99))],
        }


class CFServer:
    def __init__(self, ratings: np.ndarray, *, capacity_extra: int = 64,
                 c_probes: int = 8, sim_tol: float = 1e-6,
                 measure: str = "cosine", seed: int = 0):
        self.n_base = int(ratings.shape[0])
        self.k_cap = int(capacity_extra)
        self.c = c_probes
        self.tol = sim_tol
        self.s_max = set0_cap(self.n_base)
        self.state: CFState = jax.jit(
            lambda R: build_state(R, capacity_extra=capacity_extra,
                                  measure=measure))(jnp.asarray(
                                      ratings, jnp.float32))
        self._key = jax.random.PRNGKey(seed)
        self.stats = ServerStats()

        self._onboard = jax.jit(lambda st, r0, probes: ts.onboard_twinsearch(
            st, r0, probes, s_max=self.s_max, n_base=self.n_base,
            k_cap=self.k_cap, tol=self.tol))
        self._onboard_trad = jax.jit(base_lib.onboard_traditional)
        self._recommend = jax.jit(knn.recommend,
                                  static_argnames=("k_neighbors", "n_rec"))
        self._predict = jax.jit(knn.predict, static_argnames=("k",))
        self._cache = None

    # -- onboarding ---------------------------------------------------------

    def onboard_user(self, ratings: np.ndarray, *,
                     use_twinsearch: bool = True) -> tuple[int, dict]:
        if int(self.state.n_active) >= self.state.capacity:
            raise RuntimeError("capacity exhausted; grow the state "
                               "(production: rotate to a larger arena)")
        r0 = jnp.asarray(ratings, jnp.float32)
        t0 = time.perf_counter()
        if use_twinsearch:
            self._key, sub = jax.random.split(self._key)
            probes = jax.random.randint(sub, (self.c,), 0, self.n_base)
            new_state, res = self._onboard(self.state, r0, probes)
            found = bool(res.found)
            self.stats.twin_hits += found
            self.stats.fallbacks += not found
            self.stats.overflows += bool(res.overflowed)
        else:
            new_state = self._onboard_trad(self.state, r0)
            self.stats.fallbacks += 1
            found = False
        new_state.n_active.block_until_ready()
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.state = new_state
        self.stats.onboarded += 1
        self.stats.onboard_ms.append(dt_ms)
        uid = int(self.state.n_active) - 1
        return uid, {"twin_found": found, "ms": dt_ms}

    # -- queries ------------------------------------------------------------

    def recommend(self, user: int, n: int = 10,
                  k_neighbors: int = 20) -> list[tuple[int, float]]:
        scores, items = self._recommend(self.state, jnp.int32(user),
                                        k_neighbors=k_neighbors, n_rec=n)
        return [(int(i), float(s)) for s, i in zip(scores, items)]

    def predict(self, user: int, item: int, k: int = 20) -> float:
        return float(self._predict(self.state, jnp.int32(user),
                                   jnp.int32(item), k=k))

    # -- maintenance --------------------------------------------------------

    def add_rating(self, user: int, item: int, rating: float) -> None:
        if self._cache is None:
            self._cache = jax.jit(upd_lib.init_cache)(self.state.ratings)
            self._add = jax.jit(upd_lib.add_rating)
        self.state, self._cache = self._add(
            self.state, self._cache, jnp.int32(user), jnp.int32(item),
            jnp.float32(rating))
