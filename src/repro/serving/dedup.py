"""Twin-request dedup for LM serving — the paper's insight transplanted
(beyond-paper, DESIGN.md §4).

TwinSearch's structure is probe -> candidate set -> exact verify -> copy.
The serving analogue: requests with identical token prefixes ("twin
prompts") share prefill compute.  Probe = cheap rolling hash of the token
ids; candidate set = hash-bucket collisions; verify = exact token
comparison; copy = reuse the computed KV cache / logits.

This is the batching-layer component: ``dedup_batch`` collapses a request
batch to its unique programs and returns the scatter map to fan results
back out.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


_P1 = np.uint64(1099511628211)
_OFF = np.uint64(14695981039346656037)


def prompt_hash(tokens: np.ndarray) -> np.ndarray:
    """(B, S) -> (B,) FNV-1a over token ids (the probe step)."""
    h = np.full(tokens.shape[0], _OFF, np.uint64)
    for t in range(tokens.shape[1]):
        h = (h ^ tokens[:, t].astype(np.uint64)) * _P1
    return h


@dataclass
class DedupPlan:
    unique_rows: np.ndarray          # (U,) indices into the original batch
    scatter: np.ndarray              # (B,) position of each request's twin
    n_unique: int

    @property
    def savings(self) -> float:
        return 1.0 - self.n_unique / max(len(self.scatter), 1)


def dedup_batch(tokens: np.ndarray) -> DedupPlan:
    """Collapse identical prompts: hash-probe, then exact verify within
    buckets (hash collisions never cause wrong sharing)."""
    B = tokens.shape[0]
    hashes = prompt_hash(tokens)
    first_of: dict = {}
    unique_rows: list[int] = []
    scatter = np.zeros(B, np.int64)
    for i in range(B):
        bucket = first_of.setdefault(int(hashes[i]), [])
        hit = -1
        for u in bucket:                      # exact verify (Relationship 2)
            if np.array_equal(tokens[i], tokens[unique_rows[u]]):
                hit = u
                break
        if hit < 0:
            hit = len(unique_rows)
            unique_rows.append(i)
            bucket.append(hit)
        scatter[i] = hit
    return DedupPlan(unique_rows=np.asarray(unique_rows, np.int64),
                     scatter=scatter, n_unique=len(unique_rows))


def fan_out(unique_results: np.ndarray, plan: DedupPlan) -> np.ndarray:
    """Scatter the unique computations back to the full batch."""
    return unique_results[plan.scatter]
