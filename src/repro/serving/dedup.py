"""Twin-request dedup for serving — the paper's insight transplanted
(beyond-paper, DESIGN.md §4), now backing both the LM and CF read paths.

TwinSearch's structure is probe -> candidate set -> exact verify -> copy.
The serving analogue: requests whose expensive computation is determined
by identical inputs ("twins") share that computation.  Probe = cheap
rolling hash; candidate set = hash-bucket collisions; verify = exact
comparison of the full rows (a hash collision can therefore never cause
wrong sharing); copy = reuse the computed result via ``fan_out``.

Two instantiations ride on the same plan machinery:

  * **LM prompts** (``dedup_batch``): rows are (B, S) token ids; twins
    share prefill compute (KV cache / logits).
  * **CF queries** (``dedup_rows``): rows are arbitrary fixed-width
    byte-comparable vectors — the CF server keys recommendation queries
    on (top-k neighbour sims, neighbour ids, the user's own rating row)
    and prediction queries on (sims, neighbour ids, item).  Users whose
    keys match bit-for-bit provably receive identical scores (the scoring
    kernel is a deterministic function of exactly those inputs), so the
    batch collapses to its unique rows before dispatch and the scored
    results fan back out.

This is the batching-layer component: a ``DedupPlan`` maps a request
batch to its unique programs and back.  Bit-level equality (float keys
are compared on their bit patterns) is deliberately conservative: it can
only miss sharing, never invent it.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


_P1 = np.uint64(1099511628211)
_OFF = np.uint64(14695981039346656037)


def _fnv1a(cols: np.ndarray) -> np.ndarray:
    """(B, S) uint-castable columns -> (B,) FNV-1a hashes (the probe)."""
    h = np.full(cols.shape[0], _OFF, np.uint64)
    for t in range(cols.shape[1]):
        h = (h ^ cols[:, t].astype(np.uint64)) * _P1
    return h


def prompt_hash(tokens: np.ndarray) -> np.ndarray:
    """(B, S) -> (B,) FNV-1a over token ids (the probe step)."""
    return _fnv1a(tokens)


@dataclass
class DedupPlan:
    unique_rows: np.ndarray          # (U,) indices into the original batch
    scatter: np.ndarray              # (B,) position of each request's twin
    n_unique: int

    @property
    def savings(self) -> float:
        return 1.0 - self.n_unique / max(len(self.scatter), 1)


def _dedup(hashes: np.ndarray, rows: np.ndarray) -> DedupPlan:
    """Hash-probe then exact verify within buckets (Relationship 2: the
    probe admits candidates, only bitwise row equality shares)."""
    B = rows.shape[0]
    first_of: dict = {}
    unique_rows: list[int] = []
    scatter = np.zeros(B, np.int64)
    for i in range(B):
        bucket = first_of.setdefault(int(hashes[i]), [])
        hit = -1
        for u in bucket:                      # exact verify
            if np.array_equal(rows[i], rows[unique_rows[u]]):
                hit = u
                break
        if hit < 0:
            hit = len(unique_rows)
            unique_rows.append(i)
            bucket.append(hit)
        scatter[i] = hit
    return DedupPlan(unique_rows=np.asarray(unique_rows, np.int64),
                     scatter=scatter, n_unique=len(unique_rows))


def dedup_batch(tokens: np.ndarray) -> DedupPlan:
    """Collapse identical (B, S) prompts: hash-probe, then exact verify
    within buckets (hash collisions never cause wrong sharing)."""
    return _dedup(prompt_hash(tokens), tokens)


def dedup_rows(rows: np.ndarray) -> DedupPlan:
    """Collapse bitwise-identical rows of an arbitrary fixed-width (B, W)
    array — the CF query-path generalisation of ``dedup_batch``.

    Rows are compared on their raw bytes: float keys dedup on bit
    patterns (NaN payloads and -0.0 vs 0.0 distinguish), which is exactly
    the "identical inputs -> identical scores" contract the query path
    needs and strictly conservative otherwise."""
    rows = np.ascontiguousarray(rows)
    B = rows.shape[0]
    flat = rows.reshape(B, -1).view(np.uint8)
    return _dedup(_fnv1a(flat.view(np.uint32) if flat.shape[1] % 4 == 0
                         else flat), flat)


def fan_out(unique_results: np.ndarray, plan: DedupPlan) -> np.ndarray:
    """Scatter the unique computations back to the full batch."""
    return unique_results[plan.scatter]
