"""Write-ahead log for the CF serving path.

The paper's economics make arena state precious: a similarity list is
cheap to *maintain* (TwinSearch copy, incremental updates, rotation's
pure data movement) but expensive to *rebuild* (the traditional O(n²m)
scan).  A crash between snapshots therefore must not cost more than a
replay of the operations since the last snapshot — never a similarity
recompute.  This log makes that true:

  * every mutating operation (``onboard`` / ``add_rating`` / ``rotate``)
    is appended **before** it is applied, as a length-prefixed,
    CRC32-checksummed record (optionally fsync'd) carrying everything
    replay needs to reproduce the op bit-exactly — the validated rating
    payload, the effective onboarding path (twinsearch vs traditional),
    and the drawn probe rows;
  * on restart, records with sequence numbers past the newest durable
    checkpoint replay on top of it through the same jitted ops, so the
    recovered arena is bit-identical to the pre-crash one;
  * a torn tail (the record being written when the process died) fails
    its length/CRC check and is truncated on open — a crash mid-append
    never corrupts the log, it just loses the in-flight record;
  * truncation is tied to the snapshot cadence: a durable checkpoint at
    sequence S drops every record with seq <= S (``truncate_through``),
    and a rollback to the snapshot at S drops every record with seq > S
    (``truncate_after``) so the log always equals "ops since the state
    the next recovery would start from".

Record payload layout: one JSON line (seq, op, scalar fields, array
manifest) followed by the raw little-endian bytes of each array.  Arrays
round-trip exactly — no text encoding of floats anywhere.
"""
from __future__ import annotations

import contextlib
import json
import logging
import os
import struct
import zlib
from dataclasses import dataclass, field

import numpy as np

log = logging.getLogger(__name__)

MAGIC = b"CFWAL1\n"
_HDR = struct.Struct("<II")            # (payload length, payload crc32)
WAL_FILE = "wal.log"


@dataclass(frozen=True)
class WalRecord:
    seq: int
    op: str                            # "onboard" | "add_rating" | "rotate" | "abort"
    fields: dict = field(default_factory=dict)
    arrays: dict = field(default_factory=dict)   # name -> np.ndarray


def _encode(rec: WalRecord) -> bytes:
    manifest = []
    blobs = []
    for name, arr in rec.arrays.items():
        a = np.ascontiguousarray(arr)
        manifest.append([name, str(a.dtype), list(a.shape)])
        blobs.append(a.tobytes())
    meta = json.dumps({"seq": rec.seq, "op": rec.op, "fields": rec.fields,
                       "arrays": manifest}).encode()
    return meta + b"\n" + b"".join(blobs)


def _decode(payload: bytes) -> WalRecord:
    nl = payload.index(b"\n")
    meta = json.loads(payload[:nl].decode())
    arrays = {}
    off = nl + 1
    for name, dtype, shape in meta["arrays"]:
        dt = np.dtype(dtype)
        n = int(np.prod(shape)) if shape else 1
        nbytes = n * dt.itemsize
        arrays[name] = np.frombuffer(
            payload[off:off + nbytes], dtype=dt).reshape(shape).copy()
        off += nbytes
    return WalRecord(seq=int(meta["seq"]), op=meta["op"],
                     fields=meta["fields"], arrays=arrays)


def _fsync_dir(path: str) -> None:
    """Best-effort directory fsync so a rename survives power loss."""
    try:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    except OSError:                     # not supported on this platform/fs
        pass


class WriteAheadLog:
    """Single append-only segment under ``wal_dir`` with torn-tail repair.

    ``fsync=True`` (the default) makes each append durable before the
    operation it logs is applied; ``fsync=False`` trades the crash-window
    of one OS buffer flush for append latency.

    ``first_seq``/``last_seq`` are the *raw* sequence bounds of the log —
    they count every intact record, including aborted ops and their
    ``abort`` compensation records that ``records()`` filters out of the
    replay stream.  Recovery leans on that distinction twice: an aborted
    prefix is not a *missing* prefix, and a sequence number consumed by an
    aborted tail must never be reissued (``records()`` would drop the new
    record as aborted on the next recovery).  ``last_seq`` rewinds to the
    rollback point on ``truncate_after`` and is unchanged by
    ``truncate_through`` (dropping a checkpointed prefix un-consumes
    nothing).
    """

    def __init__(self, wal_dir: str, *, fsync: bool = True):
        os.makedirs(wal_dir, exist_ok=True)
        self.dir = wal_dir
        self.path = os.path.join(wal_dir, WAL_FILE)
        self.fsync = bool(fsync)
        self.appended = 0
        self.truncations = 0
        self.syncs = 0                     # actual write+fsync round-trips
        self._batch_depth = 0
        self._pending: list[bytes] = []    # encoded frames awaiting flush
        if not os.path.exists(self.path):
            with open(self.path, "wb") as f:
                f.write(MAGIC)
                f.flush()
                os.fsync(f.fileno())
            _fsync_dir(wal_dir)
        self.first_seq, self.last_seq, self._n_records = self._repair_tail()
        self._f = open(self.path, "ab")

    # -- scan / repair ------------------------------------------------------

    def _scan(self) -> tuple[list[WalRecord], int]:
        """All intact records + the byte offset where intact data ends."""
        records: list[WalRecord] = []
        with open(self.path, "rb") as f:
            head = f.read(len(MAGIC))
            if head != MAGIC:
                log.error("WAL %s has a bad magic header; treating as empty",
                          self.path)
                return [], len(MAGIC)
            good_end = f.tell()
            while True:
                hdr = f.read(_HDR.size)
                if len(hdr) < _HDR.size:
                    break                        # clean EOF or torn header
                length, crc = _HDR.unpack(hdr)
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    break                        # torn/corrupt tail record
                try:
                    records.append(_decode(payload))
                except Exception:                # undecodable despite CRC
                    break
                good_end = f.tell()
        return records, good_end

    def _repair_tail(self) -> tuple[int, int, int]:
        records, good_end = self._scan()
        size = os.path.getsize(self.path)
        if good_end < size:
            log.warning("WAL %s: truncating torn tail (%d -> %d bytes)",
                        self.path, size, good_end)
            with open(self.path, "r+b") as f:
                f.truncate(good_end)
                f.flush()
                os.fsync(f.fileno())
        first = records[0].seq if records else 0
        last = records[-1].seq if records else 0
        return first, last, len(records)

    # -- append / read ------------------------------------------------------

    def append(self, seq: int, op: str, fields: dict | None = None,
               arrays: dict | None = None) -> None:
        payload = _encode(WalRecord(seq=seq, op=op, fields=fields or {},
                                    arrays=arrays or {}))
        frame = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
        if self._batch_depth > 0:
            self._pending.append(frame)
        else:
            self._f.write(frame)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self.syncs += 1
        if self._n_records == 0:
            self.first_seq = seq
        self.last_seq = seq
        self._n_records += 1
        self.appended += 1

    # -- group commit -------------------------------------------------------

    def flush(self) -> None:
        """Write every buffered frame in one write + (optional) fsync.

        Durability granularity under a batch is the batch: a crash before
        flush loses the *whole* pending group, never a prefix of committed
        records followed by a gap — the frames hit the file in one
        contiguous write, and a torn write truncates from the tear."""
        if not self._pending:
            return
        self._f.write(b"".join(self._pending))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.syncs += 1
        self._pending.clear()

    @contextlib.contextmanager
    def batch(self):
        """Coalesce appends inside the block into a single flush at exit.

        Nests: only the outermost batch flushes.  Any read or truncation
        during the batch flushes first, so buffered records are never
        invisible to the log's own API."""
        self._batch_depth += 1
        try:
            yield self
        finally:
            self._batch_depth -= 1
            if self._batch_depth == 0:
                self.flush()

    def records(self, after_seq: int = 0) -> list[WalRecord]:
        """Intact records with seq > ``after_seq``, in append order,
        with aborted operations (compensation records) filtered out."""
        self.flush()
        recs, _ = self._scan()
        aborted = {r.fields.get("target") for r in recs if r.op == "abort"}
        return [r for r in recs
                if r.seq > after_seq and r.op != "abort"
                and r.seq not in aborted]

    def __len__(self) -> int:
        return self._n_records

    def size_bytes(self) -> int:
        self.flush()
        return os.path.getsize(self.path)

    # -- truncation ---------------------------------------------------------

    def truncate_through(self, seq: int) -> None:
        """Drop records with seq <= ``seq`` — a durable checkpoint at
        ``seq`` has subsumed them.  ``last_seq`` is unchanged: dropping a
        checkpointed prefix un-consumes no sequence numbers."""
        self._rewrite(lambda r: r.seq > seq, last_seq=self.last_seq)

    def truncate_after(self, seq: int) -> None:
        """Drop records with seq > ``seq`` — a rollback discarded their
        effects.  ``last_seq`` rewinds to ``seq`` (even when every record
        is dropped) so the discarded sequence numbers are reissued, in
        lockstep with the server's own counter."""
        self._rewrite(lambda r: r.seq <= seq,
                      last_seq=min(self.last_seq, seq))

    def _rewrite(self, keep, *, last_seq: int) -> None:
        self.flush()
        recs, _ = self._scan()
        kept = [r for r in recs if keep(r)]
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(MAGIC)
            for r in kept:
                payload = _encode(r)
                f.write(_HDR.pack(len(payload), zlib.crc32(payload)))
                f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)               # atomic publish
        _fsync_dir(self.dir)
        self._f = open(self.path, "ab")
        self._n_records = len(kept)
        self.first_seq = kept[0].seq if kept else 0
        self.last_seq = last_seq
        self.truncations += 1

    def close(self) -> None:
        try:
            self.flush()
        except Exception:
            pass
        try:
            self._f.close()
        except Exception:
            pass
