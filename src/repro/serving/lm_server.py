"""Batched LM decode loop: prefill once, decode autoregressively, with the
twin-prompt dedup plan collapsing identical requests before prefill."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import transformer as lm
from repro.serving.dedup import DedupPlan, dedup_batch, fan_out


class LMServer:
    def __init__(self, params: dict, cfg: LMConfig, max_len: int = 1024):
        self.params, self.cfg, self.max_len = params, cfg, max_len
        self._prefill = jax.jit(lambda p, t: lm.prefill(p, t, cfg))
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))

    def generate(self, tokens: np.ndarray, n_new: int,
                 dedup: bool = True, greedy: bool = True,
                 key: jax.Array | None = None) -> tuple[np.ndarray, dict]:
        """tokens: (B, S) prompts (equal length) -> (B, n_new) completions.

        With ``dedup`` the batch collapses to unique prompts (the paper's
        twin insight at the serving layer); identical prompts share prefill
        *and* decode compute under greedy sampling.
        """
        B, S = tokens.shape
        assert S + n_new <= self.max_len
        plan: DedupPlan | None = None
        work = tokens
        if dedup and greedy:
            plan = dedup_batch(tokens)
            work = tokens[plan.unique_rows]

        logits, cache = self._prefill(self.params, jnp.asarray(work))
        # Grow the global cache to max_len for decode appends.
        pad = self.max_len - S
        cache = dict(cache)
        for k in ("kg", "vg"):
            if k in cache:
                cache[k] = jnp.pad(cache[k],
                                   ((0, 0), (0, 0), (0, pad), (0, 0),
                                    (0, 0)))
        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for i in range(n_new):
            out.append(np.asarray(tok[:, 0]))
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(S + i))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        completions = np.stack(out, axis=1)              # (U, n_new)
        info = {"prefill_rows": work.shape[0], "batch": B,
                "dedup_savings": plan.savings if plan else 0.0}
        if plan is not None:
            completions = fan_out(completions, plan)
        return completions, info
