"""Consolidated configuration surface for ``CFServer``.

``CFServer.__init__`` grew one keyword at a time across the resilience,
durability, and replication PRs — nineteen flat knobs whose grouping
(snapshotting vs WAL vs rotation vs the degradation ladder) lived only in
the docstring.  ``ServerConfig`` makes the grouping structural: four
frozen sub-configs plus the core arena knobs, constructible from the old
flat kwargs (``ServerConfig.from_kwargs``) and flattenable back
(``to_kwargs``) so the legacy shim round-trips losslessly.

All dataclasses are frozen: a server's configuration is immutable for its
lifetime; derive variants with ``dataclasses.replace``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields, replace
from typing import Any

from repro.serving.guard import RetryPolicy


@dataclass(frozen=True)
class SnapshotConfig:
    """Snapshot / rollback cadence (legacy ``snapshot_*`` / ``check_every``)."""
    every: int = 64          # healthy onboards between snapshots
    dir: str | None = None   # durable checkpoints when set (else in-mem only)
    keep: int = 3            # durable checkpoints retained
    check_every: int = 8     # onboards between arena_healthy sweeps


@dataclass(frozen=True)
class WalConfig:
    """Write-ahead log (legacy ``wal_dir`` / ``wal_fsync``) + this PR's
    group-commit and batched-replay knobs."""
    dir: str | None = None   # WAL enabled when set
    fsync: bool = True       # fsync each commit (power-loss durability)
    group_commit: bool = True   # coalesce batch appends into one fsync
    replay_batch: int = 16   # records per jitted replay chunk (1 = serial)


@dataclass(frozen=True)
class RotationConfig:
    """Arena rotation (legacy ``rotate_headroom``) + incremental rotation.

    ``budget_rows == 0`` (default) keeps the classic synchronous rotation:
    the triggering onboard pays the whole compaction.  ``budget_rows > 0``
    switches to the chunked plan: rotation starts when free write slots
    drop to ``reserve_slots`` and each onboard/tick merges at most
    ``budget_rows`` base rows, with the atomic swap deferred until the
    plan completes (or the buffer truly fills, which force-drains)."""
    headroom: float = 1.0
    budget_rows: int = 0
    reserve_slots: int | None = None   # None -> max(1, k_cap // 4)


@dataclass(frozen=True)
class LadderConfig:
    """Degradation ladder + retry (legacy ``retry`` / ``monitor`` /
    ``recover_after`` / ``shed_cooldown_s``)."""
    recover_after: int = 32
    shed_cooldown_s: float = 1.0
    drain_on_shed: bool = True   # shed backpressure time drains rotation
    retry: RetryPolicy | None = None
    monitor: Any = None          # StragglerMonitor (duck-typed, mutable)


@dataclass(frozen=True)
class ServerConfig:
    """Everything ``CFServer`` is told at construction, grouped."""
    capacity_extra: int = 64
    c_probes: int = 8
    sim_tol: float = 1e-6
    measure: str = "cosine"
    seed: int = 0
    rating_range: tuple[float, float] = (1.0, 5.0)
    quarantine_capacity: int = 256
    latency_window: int = 1024
    replication: Any = None      # distributed.replication.ReplicationConfig
    snapshot: SnapshotConfig = field(default_factory=SnapshotConfig)
    wal: WalConfig = field(default_factory=WalConfig)
    rotation: RotationConfig = field(default_factory=RotationConfig)
    ladder: LadderConfig = field(default_factory=LadderConfig)

    # -- legacy flat-kwarg bridge ------------------------------------------

    @classmethod
    def from_kwargs(cls, **kw: Any) -> "ServerConfig":
        """Build a config from ``CFServer``'s historical flat kwargs.

        Unknown keys raise ``TypeError`` (same contract as the old
        signature).  Emitting the ``DeprecationWarning`` is the caller's
        job — this classmethod is also the documented migration helper."""
        cfg = cls()
        snap: dict[str, Any] = {}
        wal: dict[str, Any] = {}
        rot: dict[str, Any] = {}
        lad: dict[str, Any] = {}
        top: dict[str, Any] = {}
        for key, val in kw.items():
            if key in _TOP_KEYS:
                top[key] = val
            elif key in _LEGACY_MAP:
                group, name = _LEGACY_MAP[key]
                {"snapshot": snap, "wal": wal,
                 "rotation": rot, "ladder": lad}[group][name] = val
            else:
                raise TypeError(
                    f"CFServer got an unexpected keyword argument {key!r}")
        return replace(
            cfg, **top,
            snapshot=replace(cfg.snapshot, **snap),
            wal=replace(cfg.wal, **wal),
            rotation=replace(cfg.rotation, **rot),
            ladder=replace(cfg.ladder, **lad))

    def to_kwargs(self) -> dict[str, Any]:
        """Flatten back to the historical kwargs (inverse of
        ``from_kwargs`` for every key; defaults are included)."""
        out: dict[str, Any] = {k: getattr(self, k) for k in _TOP_KEYS}
        groups = {"snapshot": self.snapshot, "wal": self.wal,
                  "rotation": self.rotation, "ladder": self.ladder}
        for legacy, (group, name) in _LEGACY_MAP.items():
            out[legacy] = getattr(groups[group], name)
        return out


_TOP_KEYS = tuple(
    f.name for f in fields(ServerConfig)
    if f.name not in ("snapshot", "wal", "rotation", "ladder"))

# legacy kwarg -> (sub-config, field)
_LEGACY_MAP = {
    "snapshot_every": ("snapshot", "every"),
    "snapshot_dir": ("snapshot", "dir"),
    "snapshot_keep": ("snapshot", "keep"),
    "check_every": ("snapshot", "check_every"),
    "wal_dir": ("wal", "dir"),
    "wal_fsync": ("wal", "fsync"),
    "wal_group_commit": ("wal", "group_commit"),
    "wal_replay_batch": ("wal", "replay_batch"),
    "rotate_headroom": ("rotation", "headroom"),
    "rotation_budget_rows": ("rotation", "budget_rows"),
    "rotation_reserve_slots": ("rotation", "reserve_slots"),
    "retry": ("ladder", "retry"),
    "monitor": ("ladder", "monitor"),
    "recover_after": ("ladder", "recover_after"),
    "shed_cooldown_s": ("ladder", "shed_cooldown_s"),
    "drain_on_shed": ("ladder", "drain_on_shed"),
}
