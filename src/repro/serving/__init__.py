from repro.serving.cf_server import CFServer, ServerStats
from repro.serving.dedup import DedupPlan, dedup_batch, fan_out, prompt_hash
from repro.serving.lm_server import LMServer

__all__ = ["CFServer", "ServerStats", "DedupPlan", "dedup_batch", "fan_out",
           "prompt_hash", "LMServer"]
