"""Public serving surface.

``__all__`` here is the supported API — ``tests/test_api_surface.py``
snapshots it (and the ``ServerConfig``/``OnboardResult`` field sets) so
the surface can only change deliberately.
"""
from repro.distributed.replication import ReplicationConfig
from repro.serving.cf_server import (CFServer, OnboardResult, ServerStats,
                                     LEVEL_DEGRADED, LEVEL_SHED,
                                     LEVEL_TRADITIONAL, LEVEL_TWINSEARCH)
from repro.serving.config import (LadderConfig, RotationConfig,
                                  ServerConfig, SnapshotConfig, WalConfig)
from repro.serving.dedup import (DedupPlan, dedup_batch, dedup_rows,
                                 fan_out, prompt_hash)
from repro.serving.guard import (Quarantine, Rejection, RetryPolicy,
                                 call_with_retry)
from repro.serving.lm_server import LMServer
from repro.serving.wal import WalRecord, WriteAheadLog

__all__ = [
    # server + results
    "CFServer", "OnboardResult", "ServerStats",
    # configuration
    "ServerConfig", "SnapshotConfig", "WalConfig", "RotationConfig",
    "LadderConfig", "ReplicationConfig",
    # degradation ladder levels
    "LEVEL_TWINSEARCH", "LEVEL_TRADITIONAL", "LEVEL_DEGRADED", "LEVEL_SHED",
    # request guard
    "Quarantine", "Rejection", "RetryPolicy", "call_with_retry",
    # durability
    "WalRecord", "WriteAheadLog",
    # twin-dedup utilities (LM prompts + CF query batches)
    "DedupPlan", "dedup_batch", "dedup_rows", "fan_out", "prompt_hash",
    "LMServer",
]
