from repro.serving.cf_server import (CFServer, ServerStats,
                                     LEVEL_DEGRADED, LEVEL_SHED,
                                     LEVEL_TRADITIONAL, LEVEL_TWINSEARCH)
from repro.serving.dedup import DedupPlan, dedup_batch, fan_out, prompt_hash
from repro.serving.guard import (Quarantine, Rejection, RetryPolicy,
                                 call_with_retry)
from repro.serving.lm_server import LMServer
from repro.serving.wal import WalRecord, WriteAheadLog

__all__ = ["CFServer", "ServerStats", "DedupPlan", "dedup_batch", "fan_out",
           "prompt_hash", "LMServer", "Quarantine", "Rejection",
           "RetryPolicy", "call_with_retry", "LEVEL_TWINSEARCH",
           "LEVEL_TRADITIONAL", "LEVEL_DEGRADED", "LEVEL_SHED",
           "WalRecord", "WriteAheadLog"]
