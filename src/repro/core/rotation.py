"""Arena rotation: grow a full ``CFState`` into a larger one without
recomputing a single similarity.

The serving arena is fixed-capacity (N = n_base + k_cap) so every mutating
op stays jit-able with static shapes.  When a traffic burst fills all
``k_cap`` onboarding slots the old behaviour was to raise — exactly at the
moment the paper's fast path is paying off.  Rotation instead *compacts*
the write region into a new, larger base arena:

  * the k onboarded users' own lists already hold sim(u_t, x) for every
    base row x — their unsorted rows are recovered by scattering each
    sorted list back through its permutation (pure data movement);
  * every base row receives all k new entries in ONE fused k-way
    merge-insert (PR 1's ``merge_new_users_into_base``) fed by that
    recovered block — O(N·(N + k)) total instead of k·O(N²), and zero
    similarity recompute;
  * the burst block's mutual similarities are completed by symmetry
    (sim(u_t, u_s) is stored in whichever of the two rows was appended
    later) and each new row gains its self-entry, making the k users
    first-class base citizens;
  * ``extra`` fresh all-sentinel slots are appended as the new write
    region.

Everything is a rearrangement of values already in the arena, so the
rotated lists are bit-exact to what the sequential insert flow would have
produced (asserted against a numpy re-sort oracle in
``tests/test_resilience.py``) and match a fresh traditional build to float
tolerance (stored sims came from ``cosine_vs_all``; a fresh build's
``cosine_matrix`` rounds differently).

Rows refreshed mid-epoch by ``add_rating`` re-sort over the *current*
active set and may therefore already contain write-region entries; rotation
gates those out before the merge so no row ends up with duplicates.

Two execution modes share the same per-row ops (so they are bit-exact by
construction):

  * ``rotate_arena`` — the one-shot synchronous rotation: compact the
    whole write region ``[n_base, n_active)`` now;
  * ``RotationPlan`` — the chunked, resumable rotation: freeze the burst
    boundary at plan start, merge base rows in bounded slices
    (``step(state, budget_rows)``) while new onboards keep landing past
    the frozen boundary, then ``finalize(state)`` performs the atomic
    swap.  Rows onboarded mid-plan are *carried* into the new write
    region unchanged (onboarding only ever writes the new user's own
    row); base rows refreshed mid-plan by ``add_rating`` are re-merged at
    finalize from the live state, and a refresh of a frozen burst row
    invalidates the recovered block and restarts the (idempotent)
    precompute.  ``finalize`` is therefore bit-identical to the one-shot
    ``rotate_arena_frozen`` applied to the live state at swap time —
    which is what crash recovery replays from the WAL's ``rotate_commit``
    record.
"""
from __future__ import annotations

import math
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL, SENTINEL_GATE
from repro.core.maintenance import merge_new_users_into_base


def unsorted_rows(sim_vals: jax.Array, sim_idx: jax.Array,
                  rows: jax.Array) -> jax.Array:
    """(k, N) unsorted similarity rows recovered from sorted lists.

    Each row's ``sim_idx`` is a permutation of 0..N-1 (argsort output), so
    scattering the sorted values back through it reconstructs the original
    column order; sentinel entries land on the columns that were inactive
    at the row's build."""
    N = sim_vals.shape[1]

    def one(v: jax.Array, i: jax.Array) -> jax.Array:
        return jnp.full((N,), SENTINEL, v.dtype).at[i].set(v)

    return jax.vmap(one)(sim_vals[rows], sim_idx[rows])


def _fit_width(vals: jax.Array, idx: jax.Array,
               width: int) -> tuple[jax.Array, jax.Array]:
    """Pad (head sentinels) or trim (head entries, sentinels by
    construction) ascending lists to ``width`` columns."""
    rows, cur = vals.shape
    if cur == width:
        return vals, idx
    if cur < width:
        pad_v = jnp.full((rows, width - cur), SENTINEL, vals.dtype)
        pad_i = jnp.full((rows, width - cur), -1, idx.dtype)
        return (jnp.concatenate([pad_v, vals], axis=1),
                jnp.concatenate([pad_i, idx], axis=1))
    return vals[:, cur - width:], idx[:, cur - width:]


# ---------------------------------------------------------------------------
# Shared per-row ops — every rotation mode goes through these, so chunked
# and one-shot results are bit-identical (pure data movement, row-local).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_base", "use_pallas"))
def _merge_base_rows(sim_vals: jax.Array, sim_idx: jax.Array, U: jax.Array,
                     rows: jax.Array, buf_ids: jax.Array, *, n_base: int,
                     use_pallas: bool | None) -> tuple[jax.Array, jax.Array]:
    """Gate + stable re-sort + k-way merge for the base rows ``rows``.

    ``rows`` is (b,) int32 ids in [0, n_base); duplicates (chunk padding)
    compute redundantly and are discarded by the caller.  Returns the
    merged ascending (b, L + k) lists.  Row-local: processing rows in any
    grouping yields bitwise-identical rows."""
    gv_raw = sim_vals[rows]
    gi_raw = sim_idx[rows]
    # Gate out any write-region entries (rows refreshed by add_rating
    # already carry them), stable re-sort so the gated lists are ascending
    # again, then merge the whole burst in one pass.
    gate = gi_raw < n_base
    gv = jnp.where(gate, gv_raw, SENTINEL)
    gi = jnp.where(gate, gi_raw, -1)
    order = jnp.argsort(gv, axis=1, stable=True)
    gv = jnp.take_along_axis(gv, order, axis=1)
    gi = jnp.take_along_axis(gi, order, axis=1)
    mv, mi = merge_new_users_into_base(gv, gi, U[:, rows], buf_ids,
                                       use_pallas=use_pallas)
    return mv, mi.astype(jnp.int32)


def _burst_rows(U: jax.Array, *, n_base: int, n_frozen: int,
                n_new: int) -> tuple[jax.Array, jax.Array]:
    """Full-width sorted lists for the compacted burst rows.

    Base entries come straight from the recovered block; burst-internal
    entries complete by symmetry (row u_t holds sim(u_t, u_s) only for
    s < t — the transpose holds the rest); the self-entry a fresh build
    would carry is exactly 1."""
    k = n_frozen - n_base
    C = U[:, n_base:n_frozen]                            # (k, k)
    C = jnp.where(C > SENTINEL_GATE, C, jnp.swapaxes(C, 0, 1))
    C = C.at[jnp.arange(k), jnp.arange(k)].set(1.0)
    W = jnp.full((k, n_new), SENTINEL, jnp.float32)
    W = W.at[:, :n_base].set(U[:, :n_base].astype(jnp.float32))
    W = W.at[:, n_base:n_frozen].set(C.astype(jnp.float32))
    bi = jnp.argsort(W, axis=1, stable=True).astype(jnp.int32)
    bv = jnp.take_along_axis(W, bi, axis=1)
    return bv, bi


def rotate_arena_frozen(state: CFState, *, n_base: int, n_frozen: int,
                        extra: int,
                        use_pallas: bool | None = None) -> CFState:
    """Compact the frozen burst ``[n_base, n_frozen)`` into a new base
    arena of capacity ``n_active + extra``; rows ``[n_frozen, n_active)``
    (onboarded after the boundary froze) are *carried* into the new write
    region with their lists re-fit to the new width — valid because
    onboarding only ever writes the new user's own row, so a carried
    row's list is exactly what onboarding into the new arena would have
    produced.  ``n_frozen == n_active`` reproduces the classic full
    rotation.  This is also the deterministic replay of a WAL
    ``rotate_commit`` record."""
    n_act = int(state.n_active)
    k = n_frozen - n_base
    n_new = n_act + extra
    m = state.n_items
    grow = n_new - n_act

    ratings = jnp.concatenate([
        state.ratings[:n_act],
        jnp.zeros((grow, m), state.ratings.dtype)], axis=0)
    norms = jnp.concatenate([
        state.norms[:n_act], jnp.zeros((grow,), state.norms.dtype)])

    if k == 0:                               # pure growth, nothing to merge
        base_v, base_i = _fit_width(state.sim_vals[:n_frozen],
                                    state.sim_idx[:n_frozen], n_new)
    else:
        buf = jnp.arange(n_base, n_frozen, dtype=jnp.int32)
        U = unsorted_rows(state.sim_vals, state.sim_idx, buf)    # (k, N)
        mv, mi = _merge_base_rows(state.sim_vals, state.sim_idx, U,
                                  jnp.arange(n_base, dtype=jnp.int32), buf,
                                  n_base=n_base, use_pallas=use_pallas)
        mv, mi = _fit_width(mv, mi, n_new)
        bv, bi = _burst_rows(U, n_base=n_base, n_frozen=n_frozen,
                             n_new=n_new)
        base_v = jnp.concatenate([mv.astype(jnp.float32), bv], axis=0)
        base_i = jnp.concatenate([mi, bi], axis=0)

    blocks_v, blocks_i = [base_v], [base_i]
    if n_act > n_frozen:                     # carried mid-plan onboards
        cv, ci = _fit_width(state.sim_vals[n_frozen:n_act],
                            state.sim_idx[n_frozen:n_act], n_new)
        blocks_v.append(cv.astype(jnp.float32))
        blocks_i.append(ci)

    # Fresh write region: all-sentinel rows with identity permutations
    # (what ``build_state`` gives inactive slots).
    empty_v = jnp.full((grow, n_new), SENTINEL, jnp.float32)
    empty_i = jnp.broadcast_to(jnp.arange(n_new, dtype=jnp.int32),
                               (grow, n_new))
    return CFState(
        ratings=ratings,
        norms=norms,
        sim_vals=jnp.concatenate(blocks_v + [empty_v], axis=0),
        sim_idx=jnp.concatenate(blocks_i + [empty_i], axis=0),
        n_active=jnp.asarray(n_act, jnp.int32),
    )


def rotate_arena(state: CFState, *, n_base: int, extra: int,
                 headroom: float = 1.0,
                 use_pallas: bool | None = None) -> CFState:
    """Compact the write region [n_base, n_active) into a new base arena of
    capacity ``n_active + extra``.  Rotation is rare (once per k_cap
    onboards) and runs un-jitted at the top level; the merge underneath is
    the jitted ``merge_insert`` op.

    ``headroom`` is the rotation *hysteresis* knob: the fresh write region
    is at least ``headroom`` times the burst just absorbed, so a sustained
    flood that fills ``extra`` slots immediately gets a proportionally
    larger buffer next time instead of re-triggering a synchronous rotation
    after the same number of onboards.  ``headroom=1.0`` (the default)
    reproduces the fixed-size behaviour."""
    n_act = int(state.n_active)
    k = n_act - n_base
    extra = max(int(extra), int(math.ceil(float(headroom) * k)))
    return rotate_arena_frozen(state, n_base=n_base, n_frozen=n_act,
                               extra=extra, use_pallas=use_pallas)


class RotationPlan:
    """Chunked, resumable arena rotation with a frozen burst boundary.

    Created when the server decides to rotate *ahead* of exhaustion; the
    expensive part — gating + merging every base row — runs in bounded
    slices (``step``) interleaved with live traffic, and the cheap
    remainder (burst-row construction, carried rows, concatenation) runs
    once at ``finalize``.  The plan is pure precompute: it never mutates
    the state it reads, a crash mid-plan loses nothing (nothing is logged
    until the swap commits), and its output is bit-identical to
    ``rotate_arena_frozen(live_state, ...)`` at swap time.

    Live mutations are reconciled through ``note_write``:

      * a base row refreshed by ``add_rating`` is marked dirty and
        re-merged from the live state before the swap;
      * a *frozen burst* row refreshed invalidates the recovered U block —
        the precompute restarts from the live state (same boundary);
      * rows at or past ``n_frozen`` (mid-plan onboards) need nothing —
        ``finalize`` carries them straight from the live state.
    """

    def __init__(self, state: CFState, *, n_base: int, extra: int,
                 chunk_rows: int = 64, use_pallas: bool | None = None):
        self.n_base = int(n_base)
        self.n_frozen = int(state.n_active)
        self.k = self.n_frozen - self.n_base
        self.extra = int(extra)
        self.chunk = max(1, int(chunk_rows))
        self.use_pallas = use_pallas
        self.restarts = 0
        self.elapsed_ms = 0.0        # accumulated step+finalize time
        self._buf = jnp.arange(self.n_base, self.n_frozen, dtype=jnp.int32)
        self._U: jax.Array | None = None
        self._mv: np.ndarray | None = None       # (n_base, L + k) host accum
        self._mi: np.ndarray | None = None
        self._cursor = 0
        self._dirty: set[int] = set()
        self._stale = self.k > 0     # U snapshot pending (or invalidated)

    # -- progress -----------------------------------------------------------

    @property
    def done(self) -> bool:
        """True when every base row is merged against the current U block
        and no dirty rows are pending — the swap would be O(burst+concat)."""
        if self.k == 0:
            return True
        return (not self._stale and self._cursor >= self.n_base
                and not self._dirty)

    @property
    def remaining_rows(self) -> int:
        if self.k == 0:
            return 0
        if self._stale:
            return self.n_base + len(self._dirty)
        return (self.n_base - self._cursor) + len(self._dirty)

    # -- live-mutation reconciliation ---------------------------------------

    def note_write(self, row: int) -> None:
        """Record that ``row``'s list/ratings were rewritten (add_rating)."""
        r = int(row)
        if r < self.n_base:
            if not self._stale:      # a pending refreeze re-reads everything
                self._dirty.add(r)
        elif r < self.n_frozen:
            # The recovered block holds this burst row's scattered list;
            # it is now stale.  Restart the precompute from the live state.
            if not self._stale:
                self._stale = True
                self.restarts += 1

    # -- bounded work -------------------------------------------------------

    def _refreeze(self, state: CFState) -> None:
        self._U = unsorted_rows(state.sim_vals, state.sim_idx, self._buf)
        L = state.sim_vals.shape[1]
        self._mv = np.empty((self.n_base, L + self.k), np.float32)
        self._mi = np.empty((self.n_base, L + self.k), np.int32)
        self._cursor = 0
        self._dirty.clear()
        self._stale = False

    def _run_rows(self, state: CFState, rows: np.ndarray) -> None:
        """One fixed-shape merge dispatch over ``rows`` (padded by
        repetition to the chunk width; pad lanes recompute a row already
        done — harmless, row-local, discarded by the scatter)."""
        n = rows.shape[0]
        if n < self.chunk:
            rows = np.concatenate(
                [rows, np.full(self.chunk - n, rows[-1], rows.dtype)])
        mv, mi = _merge_base_rows(state.sim_vals, state.sim_idx, self._U,
                                  jnp.asarray(rows, jnp.int32), self._buf,
                                  n_base=self.n_base,
                                  use_pallas=self.use_pallas)
        self._mv[rows[:n]] = np.asarray(mv)[:n]
        self._mi[rows[:n]] = np.asarray(mi)[:n]

    def step(self, state: CFState, budget_rows: int) -> int:
        """Merge up to ``budget_rows`` base rows against the frozen block;
        returns the number of rows actually processed.  Never mutates
        ``state``; safe to call at any point between server mutations."""
        if self.k == 0 or self.done:
            return 0
        import time
        t0 = time.perf_counter()
        if self._stale:
            self._refreeze(state)
        budget = max(1, int(budget_rows))
        processed = 0
        while processed < budget and self._cursor < self.n_base:
            hi = min(self._cursor + self.chunk, self.n_base)
            self._run_rows(state, np.arange(self._cursor, hi))
            processed += hi - self._cursor
            self._cursor = hi
        # Main sweep finished: re-merge rows dirtied since they were done.
        while processed < budget and self._cursor >= self.n_base \
                and self._dirty:
            batch = sorted(self._dirty)[:self.chunk]
            self._run_rows(state, np.asarray(batch))
            self._dirty.difference_update(batch)
            processed += len(batch)
        self.elapsed_ms += (time.perf_counter() - t0) * 1e3
        return processed

    # -- the atomic swap ----------------------------------------------------

    def finalize(self, state: CFState) -> CFState:
        """Produce the rotated state from the live ``state``: drain any
        remaining/dirty rows, build the burst + carried blocks, and
        assemble the new arena.  Bit-identical to
        ``rotate_arena_frozen(state, n_base=.., n_frozen=.., extra=..)``."""
        while not self.done:                     # force-drain the tail
            self.step(state, self.n_base)
        import time
        t0 = time.perf_counter()
        n_act = int(state.n_active)
        n_new = n_act + self.extra
        m = state.n_items
        grow = n_new - n_act

        ratings = jnp.concatenate([
            state.ratings[:n_act],
            jnp.zeros((grow, m), state.ratings.dtype)], axis=0)
        norms = jnp.concatenate([
            state.norms[:n_act], jnp.zeros((grow,), state.norms.dtype)])

        if self.k == 0:
            base_v, base_i = _fit_width(state.sim_vals[:self.n_frozen],
                                        state.sim_idx[:self.n_frozen], n_new)
        else:
            mv, mi = _fit_width(jnp.asarray(self._mv),
                                jnp.asarray(self._mi), n_new)
            bv, bi = _burst_rows(self._U, n_base=self.n_base,
                                 n_frozen=self.n_frozen, n_new=n_new)
            base_v = jnp.concatenate([mv.astype(jnp.float32), bv], axis=0)
            base_i = jnp.concatenate([mi, bi], axis=0)

        blocks_v, blocks_i = [base_v], [base_i]
        if n_act > self.n_frozen:
            cv, ci = _fit_width(state.sim_vals[self.n_frozen:n_act],
                                state.sim_idx[self.n_frozen:n_act], n_new)
            blocks_v.append(cv.astype(jnp.float32))
            blocks_i.append(ci)

        empty_v = jnp.full((grow, n_new), SENTINEL, jnp.float32)
        empty_i = jnp.broadcast_to(jnp.arange(n_new, dtype=jnp.int32),
                                   (grow, n_new))
        out = CFState(
            ratings=ratings,
            norms=norms,
            sim_vals=jnp.concatenate(blocks_v + [empty_v], axis=0),
            sim_idx=jnp.concatenate(blocks_i + [empty_i], axis=0),
            n_active=jnp.asarray(n_act, jnp.int32),
        )
        self.elapsed_ms += (time.perf_counter() - t0) * 1e3
        return out
