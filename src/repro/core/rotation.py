"""Arena rotation: grow a full ``CFState`` into a larger one without
recomputing a single similarity.

The serving arena is fixed-capacity (N = n_base + k_cap) so every mutating
op stays jit-able with static shapes.  When a traffic burst fills all
``k_cap`` onboarding slots the old behaviour was to raise — exactly at the
moment the paper's fast path is paying off.  Rotation instead *compacts*
the write region into a new, larger base arena:

  * the k onboarded users' own lists already hold sim(u_t, x) for every
    base row x — their unsorted rows are recovered by scattering each
    sorted list back through its permutation (pure data movement);
  * every base row receives all k new entries in ONE fused k-way
    merge-insert (PR 1's ``merge_new_users_into_base``) fed by that
    recovered block — O(N·(N + k)) total instead of k·O(N²), and zero
    similarity recompute;
  * the burst block's mutual similarities are completed by symmetry
    (sim(u_t, u_s) is stored in whichever of the two rows was appended
    later) and each new row gains its self-entry, making the k users
    first-class base citizens;
  * ``extra`` fresh all-sentinel slots are appended as the new write
    region.

Everything is a rearrangement of values already in the arena, so the
rotated lists are bit-exact to what the sequential insert flow would have
produced (asserted against a numpy re-sort oracle in
``tests/test_resilience.py``) and match a fresh traditional build to float
tolerance (stored sims came from ``cosine_vs_all``; a fresh build's
``cosine_matrix`` rounds differently).

Rows refreshed mid-epoch by ``add_rating`` re-sort over the *current*
active set and may therefore already contain write-region entries; rotation
gates those out before the merge so no row ends up with duplicates.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL, SENTINEL_GATE
from repro.core.maintenance import merge_new_users_into_base


def unsorted_rows(sim_vals: jax.Array, sim_idx: jax.Array,
                  rows: jax.Array) -> jax.Array:
    """(k, N) unsorted similarity rows recovered from sorted lists.

    Each row's ``sim_idx`` is a permutation of 0..N-1 (argsort output), so
    scattering the sorted values back through it reconstructs the original
    column order; sentinel entries land on the columns that were inactive
    at the row's build."""
    N = sim_vals.shape[1]

    def one(v: jax.Array, i: jax.Array) -> jax.Array:
        return jnp.full((N,), SENTINEL, v.dtype).at[i].set(v)

    return jax.vmap(one)(sim_vals[rows], sim_idx[rows])


def _fit_width(vals: jax.Array, idx: jax.Array,
               width: int) -> tuple[jax.Array, jax.Array]:
    """Pad (head sentinels) or trim (head entries, sentinels by
    construction) ascending lists to ``width`` columns."""
    rows, cur = vals.shape
    if cur == width:
        return vals, idx
    if cur < width:
        pad_v = jnp.full((rows, width - cur), SENTINEL, vals.dtype)
        pad_i = jnp.full((rows, width - cur), -1, idx.dtype)
        return (jnp.concatenate([pad_v, vals], axis=1),
                jnp.concatenate([pad_i, idx], axis=1))
    return vals[:, cur - width:], idx[:, cur - width:]


def rotate_arena(state: CFState, *, n_base: int, extra: int,
                 headroom: float = 1.0,
                 use_pallas: bool | None = None) -> CFState:
    """Compact the write region [n_base, n_active) into a new base arena of
    capacity ``n_active + extra``.  Rotation is rare (once per k_cap
    onboards) and runs un-jitted at the top level; the merge underneath is
    the jitted ``merge_insert`` op.

    ``headroom`` is the rotation *hysteresis* knob: the fresh write region
    is at least ``headroom`` times the burst just absorbed, so a sustained
    flood that fills ``extra`` slots immediately gets a proportionally
    larger buffer next time instead of re-triggering a synchronous rotation
    after the same number of onboards.  ``headroom=1.0`` (the default)
    reproduces the fixed-size behaviour."""
    n_act = int(state.n_active)
    k = n_act - n_base
    extra = max(int(extra), int(math.ceil(float(headroom) * k)))
    n_new = n_act + extra
    m = state.n_items

    ratings = jnp.concatenate([
        state.ratings[:n_act],
        jnp.zeros((extra, m), state.ratings.dtype)], axis=0)
    norms = jnp.concatenate([
        state.norms[:n_act], jnp.zeros((extra,), state.norms.dtype)])

    if k == 0:                               # pure growth, nothing to merge
        base_v, base_i = _fit_width(state.sim_vals[:n_act],
                                    state.sim_idx[:n_act], n_new)
    else:
        buf = jnp.arange(n_base, n_act, dtype=jnp.int32)
        U = unsorted_rows(state.sim_vals, state.sim_idx, buf)    # (k, N)

        # Base rows: gate out any write-region entries (rows refreshed by
        # add_rating already carry them), stable re-sort so the gated lists
        # are ascending again, then merge the whole burst in one pass.
        gate = state.sim_idx[:n_base] < n_base
        gv = jnp.where(gate, state.sim_vals[:n_base], SENTINEL)
        gi = jnp.where(gate, state.sim_idx[:n_base], -1)
        order = jnp.argsort(gv, axis=1, stable=True)
        gv = jnp.take_along_axis(gv, order, axis=1)
        gi = jnp.take_along_axis(gi, order, axis=1)
        mv, mi = merge_new_users_into_base(
            gv, gi, U[:, :n_base], buf, use_pallas=use_pallas)
        mv, mi = _fit_width(mv, mi.astype(jnp.int32), n_new)

        # Burst rows: base entries come straight from the recovered block;
        # burst-internal entries complete by symmetry (row u_t holds
        # sim(u_t, u_s) only for s < t — the transpose holds the rest);
        # the self-entry a fresh build would carry is exactly 1.
        C = U[:, n_base:n_act]                               # (k, k)
        C = jnp.where(C > SENTINEL_GATE, C, jnp.swapaxes(C, 0, 1))
        C = C.at[jnp.arange(k), jnp.arange(k)].set(1.0)
        W = jnp.full((k, n_new), SENTINEL, jnp.float32)
        W = W.at[:, :n_base].set(U[:, :n_base].astype(jnp.float32))
        W = W.at[:, n_base:n_act].set(C.astype(jnp.float32))
        bi = jnp.argsort(W, axis=1, stable=True).astype(jnp.int32)
        bv = jnp.take_along_axis(W, bi, axis=1)
        base_v = jnp.concatenate([mv.astype(jnp.float32), bv], axis=0)
        base_i = jnp.concatenate([mi, bi], axis=0)

    # Fresh write region: all-sentinel rows with identity permutations
    # (what ``build_state`` gives inactive slots).
    empty_v = jnp.full((extra, n_new), SENTINEL, jnp.float32)
    empty_i = jnp.broadcast_to(jnp.arange(n_new, dtype=jnp.int32),
                               (extra, n_new))
    return CFState(
        ratings=ratings,
        norms=norms,
        sim_vals=jnp.concatenate([base_v, empty_v], axis=0),
        sim_idx=jnp.concatenate([base_i, empty_i], axis=0),
        n_active=jnp.asarray(n_act, jnp.int32),
    )
