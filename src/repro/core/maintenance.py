"""Sorted-list maintenance: making freshly-onboarded users visible in every
existing user's ascending similarity list.

The paper measures only the *construction* of the new user's own list; a
production system must eventually also insert the new user into other
users' lists.  Both onboarding paths share these ops so the paper's
comparison is unaffected:

  * traditional path — ``sims`` (the new user's similarity to everyone) was
    just computed, so each row x inserts value sims[x] at its searchsorted
    position;
  * twin path — sim(x, u0) == sim(x, twin), which already sits in row x at
    the twin's position, so the insert duplicates the twin's entry ("twin
    splice"), requiring no new similarity computation — the paper's insight
    extended to list maintenance (beyond-paper).

Cost model (the reason the batched API exists).  One insert is a
searchsorted + full shift-gather over the (N, N) arena: O(N²).  A burst of
k users onboarded one at a time therefore pays

    k · O(N²)           (k full HBM round-trips of the arena)

while the fused k-way merge-insert (``repro/kernels/list_merge``) pays

    O(N · (N + k))      (one searchsorted over k values per row + one
                         merge-gather; the arena streams through once)

— at MovieLens scale (943×1682, k=30) the batched pass is >3× faster
wall-clock and element-identical to the k sequential inserts (asserted in
``benchmarks/maintenance_bench.py`` and ``tests/test_maintenance_batch.py``).

Burst semantics: inserts apply in burst order, and row x takes the insert
for new user u_t iff x < u_t.  That reproduces exactly the interleaved
sequential flow ``for t: append_user(u_t); insert_into_lists(u_t)`` — when
u_t is inserted, rows u_{t+1}.. do not exist yet and row u_t never receives
its own entry (its list is written by the append).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL
from repro.kernels.list_merge.ops import merge_insert


def insert_batch_into_lists(state: CFState, new_users: jax.Array,
                            sims_block: jax.Array, *,
                            use_pallas: bool | None = None) -> CFState:
    """Merge a burst of k new users into every active row's list at once.

    Args:
      state:      arena with the k users already appended (slots in
                  ``new_users`` hold their rows/lists).
      new_users:  (k,) int32 slot ids in append order (ascending).
      sims_block: (k, N) — sims_block[t, x] = sim(u_t, x).
      use_pallas: backend override for the merge kernel (None = auto).

    Row x takes insert t iff x < new_users[t] (see module docstring), so
    the result is element-identical to the interleaved append/insert loop.
    """
    N = state.capacity
    rows = jnp.arange(N, dtype=jnp.int32)[:, None]
    new_users = jnp.asarray(new_users, jnp.int32)
    mask = rows < new_users[None, :]                    # (N, k)
    vals, idx = merge_insert(
        state.sim_vals, state.sim_idx,
        jnp.swapaxes(sims_block, 0, 1).astype(state.sim_vals.dtype),
        new_users, mask, use_pallas=use_pallas)
    return state._replace(sim_vals=vals.astype(state.sim_vals.dtype),
                          sim_idx=idx)


def insert_into_lists(state: CFState, new_user: jax.Array,
                      sims: jax.Array) -> CFState:
    """Insert one ``new_user`` into every active row's ascending list.

    Rows are padded at the head with SENTINEL for inactive entries, so an
    insert drops one sentinel (or, at full capacity, the current minimum)
    and shifts the prefix left — the k=1 case of the batched merge.  Kept
    with its original gate, ``(row < n_active) & (row != new_user)``, for
    single-user onboarding callers.
    """
    N = state.capacity
    rows = jnp.arange(N, dtype=jnp.int32)
    live = (rows < state.n_active) & (rows != new_user)
    vals, idx = merge_insert(
        state.sim_vals, state.sim_idx,
        sims[:, None].astype(state.sim_vals.dtype),
        jnp.asarray(new_user, jnp.int32)[None], live[:, None])
    return state._replace(sim_vals=vals.astype(state.sim_vals.dtype),
                          sim_idx=idx)


def twin_sims_block(state: CFState, twins: jax.Array) -> jax.Array:
    """(k, N) sims gathered from each row's stored twin entries — the twin
    splice's input, computed without any similarity arithmetic.

    One O(N²) scatter inverts every row's sorted-order permutation, then
    each of the k twins is a single (N,) gather: O(N·(N + k)) total versus
    k masked argmax scans (k·O(N²)) one twin at a time.
    """
    N = state.capacity
    rows = jnp.arange(N, dtype=jnp.int32)[:, None]
    cols = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None, :], (N, N))
    inv = jnp.zeros((N, N), jnp.int32).at[rows, state.sim_idx].set(cols)
    pos = inv[:, twins.astype(jnp.int32)]               # (N, k)
    return jnp.swapaxes(jnp.take_along_axis(state.sim_vals, pos, axis=1),
                        0, 1)


def splice_twins(state: CFState, new_users: jax.Array, twins: jax.Array, *,
                 use_pallas: bool | None = None) -> CFState:
    """Twin-path maintenance for a whole burst, vectorised: row x's value
    for new user u_t equals its stored value for twins[t], so the sims
    block is a pure gather and the burst lands in one fused merge."""
    return insert_batch_into_lists(
        state, new_users, twin_sims_block(state, twins),
        use_pallas=use_pallas)


def splice_twin(state: CFState, new_user: jax.Array, twin: jax.Array
                ) -> CFState:
    """Single-user twin-path maintenance (k=1 compatibility wrapper):
    gathers sim(x, twin) from the unsorted view and defers to the shared
    insert."""
    hit = state.sim_idx == twin                          # (N, N) one-hot
    pos = jnp.argmax(hit, axis=1)
    sims = jnp.take_along_axis(state.sim_vals, pos[:, None], axis=1)[:, 0]
    return insert_into_lists(state, new_user, sims)


def merge_new_users_into_base(base_vals: jax.Array, base_idx: jax.Array,
                              sims_block: jax.Array,
                              new_user_ids: jax.Array, *,
                              use_pallas: bool | None = None
                              ) -> tuple[jax.Array, jax.Array]:
    """Immutable-base maintenance for the write-buffer onboarding paths.

    Extends each of the Nb base rows' (Nb, L) lists by k head sentinels and
    merges the burst in: the k inserts (real sims, all > SENTINEL) consume
    exactly the k sentinels, so the output (Nb, L + k) lists contain every
    original entry plus one entry per new user — what the arena flow would
    produce, without writing the base state.

    Args:
      sims_block:   (k, Nb) — sims_block[t, x] = sim(u_t, base row x); the
                    buffered onboarding paths already hold this as the
                    unsorted write buffer's base columns.
      new_user_ids: (k,) int32 ids the merged entries carry.
    """
    Nb, _ = base_vals.shape
    k = sims_block.shape[0]
    pad_v = jnp.full((Nb, k), SENTINEL, base_vals.dtype)
    pad_i = jnp.full((Nb, k), -1, jnp.int32)            # always consumed
    vals = jnp.concatenate([pad_v, base_vals], axis=1)
    idx = jnp.concatenate([pad_i, base_idx.astype(jnp.int32)], axis=1)
    return merge_insert(vals, idx,
                        jnp.swapaxes(sims_block, 0, 1).astype(vals.dtype),
                        jnp.asarray(new_user_ids, jnp.int32), None,
                        use_pallas=use_pallas)
