"""Sorted-list maintenance: inserting a freshly-onboarded user into every
existing user's list.

The paper measures only the *construction* of the new user's own list; a
production system must eventually also make the new user visible in other
users' lists.  Both onboarding paths share this op so the paper's comparison
is unaffected:

  * traditional path — ``sims`` (the new user's similarity to everyone) was
    just computed, so each row x inserts value sims[x] at its searchsorted
    position;
  * twin path — sim(x, u0) == sim(x, twin), which already sits in row x at
    the twin's position, so the insert duplicates the twin's entry ("twin
    splice"), requiring no new similarity computation — the paper's insight
    extended to list maintenance (beyond-paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL


def insert_into_lists(state: CFState, new_user: jax.Array,
                      sims: jax.Array) -> CFState:
    """Insert ``new_user`` into every active row's ascending list.

    Rows are padded at the head with SENTINEL for inactive entries, so an
    insert drops one sentinel and shifts the prefix left:

      out[j] = row[j+1]            j < p−1
      out[p−1] = (sims[x], new_user)
      out[j] = row[j]              j ≥ p
    """
    N = state.capacity
    pos = jax.vmap(lambda row, s: jnp.searchsorted(row, s, side="right"))(
        state.sim_vals, sims)                           # (N,) insert pos
    j = jnp.arange(N, dtype=jnp.int32)[None, :]
    p = pos[:, None].astype(jnp.int32)
    src = jnp.where(j < p - 1, j + 1, j)                # gather plan
    vals = jnp.take_along_axis(state.sim_vals, src, axis=1)
    idxs = jnp.take_along_axis(state.sim_idx, src, axis=1)
    at_insert = j == (p - 1)
    vals = jnp.where(at_insert, sims[:, None].astype(vals.dtype), vals)
    idxs = jnp.where(at_insert, jnp.int32(new_user), idxs)

    row_ids = jnp.arange(N, dtype=jnp.int32)
    live = (row_ids < state.n_active) & (row_ids != new_user)
    vals = jnp.where(live[:, None], vals, state.sim_vals)
    idxs = jnp.where(live[:, None], idxs, state.sim_idx)
    return state._replace(sim_vals=vals, sim_idx=idxs)


def splice_twin(state: CFState, new_user: jax.Array, twin: jax.Array
                ) -> CFState:
    """Twin-path maintenance without any similarity computation: row x's
    value for the new user equals its stored value for the twin.  Gathers
    sim(x, twin) from the *unsorted* view by scanning each row for the twin's
    index, then defers to the shared insert."""
    # Position of `twin` in each row's permutation (one masked argmax per
    # row; O(N) per row, bandwidth-bound — the same cost class as the shift
    # the insert itself performs).
    hit = state.sim_idx == twin                          # (N, N) one-hot
    pos = jnp.argmax(hit, axis=1)
    sims = jnp.take_along_axis(state.sim_vals, pos[:, None], axis=1)[:, 0]
    return insert_into_lists(state, new_user, sims)
