"""Traditional new-user similarity-list construction (the paper's baseline).

For a new user u0: compute sim(u0, x) for every active user x — O(n m) — and
sort — O(n log n).  This is the path TwinSearch displaces; it is also
TwinSearch's fallback when no twin verifies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL, active_mask
from repro.core.similarity import cosine_vs_all


def build_list(state: CFState, r0: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Similarity list of a new user vs the whole active system.

    Returns (vals_sorted_asc, idx_sorted, sims_unsorted) padded to capacity
    with SENTINEL for inactive slots.  ``sims_unsorted`` feeds the optional
    list-maintenance op (inserting u0 into existing users' lists)."""
    sims = cosine_vs_all(state.ratings, state.norms, r0)
    sims = jnp.where(active_mask(state), sims, SENTINEL)
    idx = jnp.argsort(sims).astype(jnp.int32)
    vals = jnp.take_along_axis(sims, idx, axis=-1)
    return vals, idx, sims


def append_user(state: CFState, r0: jax.Array, vals: jax.Array,
                idx: jax.Array) -> CFState:
    """Write the new user into the next capacity slot (static shapes)."""
    slot = state.n_active
    r0f = r0.astype(state.ratings.dtype)
    return CFState(
        ratings=jax.lax.dynamic_update_index_in_dim(
            state.ratings, r0f, slot, axis=0),
        norms=state.norms.at[slot].set(jnp.linalg.norm(
            r0.astype(jnp.float32))),
        sim_vals=jax.lax.dynamic_update_index_in_dim(
            state.sim_vals, vals.astype(state.sim_vals.dtype), slot, axis=0),
        sim_idx=jax.lax.dynamic_update_index_in_dim(
            state.sim_idx, idx.astype(jnp.int32), slot, axis=0),
        n_active=state.n_active + 1,
    )


def onboard_traditional(state: CFState, r0: jax.Array) -> CFState:
    """One new user through the traditional path (compute-all + sort)."""
    vals, idx, _ = build_list(state, r0)
    return append_user(state, r0, vals, idx)


def onboard_batch_traditional(state: CFState, R_new: jax.Array) -> CFState:
    """k new users, each via the traditional path — the paper's O(k n m)."""
    def step(st, r0):
        return onboard_traditional(st, r0), ()
    state, _ = jax.lax.scan(step, state, R_new)
    return state
