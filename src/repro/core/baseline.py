"""Traditional new-user similarity-list construction (the paper's baseline).

For a new user u0: compute sim(u0, x) for every active user x — O(n m) — and
sort — O(n log n).  This is the path TwinSearch displaces; it is also
TwinSearch's fallback when no twin verifies.

The batched burst (``onboard_batch_traditional``) fuses the k per-user
matvecs into one (k, m) × (m, N) ``similarity_pallas`` matmul: the ratings
arena streams through the MXU once instead of k times, and the per-step
active mask (user t sees only rows < n_base + t) is applied to the result
block before the vectorised per-row sort.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL, active_mask
from repro.core.similarity import cosine_vs_all


def build_list(state: CFState, r0: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Similarity list of a new user vs the whole active system.

    Returns (vals_sorted_asc, idx_sorted, sims_unsorted) padded to capacity
    with SENTINEL for inactive slots.  ``sims_unsorted`` feeds the optional
    list-maintenance op (inserting u0 into existing users' lists)."""
    sims = cosine_vs_all(state.ratings, state.norms, r0)
    sims = jnp.where(active_mask(state), sims, SENTINEL)
    idx = jnp.argsort(sims).astype(jnp.int32)
    vals = jnp.take_along_axis(sims, idx, axis=-1)
    return vals, idx, sims


def append_user(state: CFState, r0: jax.Array, vals: jax.Array,
                idx: jax.Array) -> CFState:
    """Write the new user into the next capacity slot (static shapes)."""
    slot = state.n_active
    r0f = r0.astype(state.ratings.dtype)
    return CFState(
        ratings=jax.lax.dynamic_update_index_in_dim(
            state.ratings, r0f, slot, axis=0),
        norms=state.norms.at[slot].set(jnp.linalg.norm(
            r0.astype(jnp.float32))),
        sim_vals=jax.lax.dynamic_update_index_in_dim(
            state.sim_vals, vals.astype(state.sim_vals.dtype), slot, axis=0),
        sim_idx=jax.lax.dynamic_update_index_in_dim(
            state.sim_idx, idx.astype(jnp.int32), slot, axis=0),
        n_active=state.n_active + 1,
    )


def onboard_traditional(state: CFState, r0: jax.Array) -> CFState:
    """One new user through the traditional path (compute-all + sort)."""
    vals, idx, _ = build_list(state, r0)
    return append_user(state, r0, vals, idx)


def onboard_batch_traditional(state: CFState, R_new: jax.Array, *,
                              fused: bool = True,
                              interpret: bool = True) -> CFState:
    """k new users via the traditional path — the paper's O(k n m).

    ``fused=True`` (default) computes every burst user's similarities in a
    single (k, m) × (m, N) Pallas matmul over the post-append ratings
    arena; ``fused=False`` keeps the sequential per-user scan (the
    reference the fused path is tested against).  Both produce user t's
    list over exactly the rows active at its append (earlier burst users
    included, later ones SENTINEL), matching the one-at-a-time flow.
    """
    if not fused:
        def step(st, r0):
            return onboard_traditional(st, r0), ()
        state, _ = jax.lax.scan(step, state, R_new)
        return state

    from repro.kernels.similarity.ops import cosine_similarity

    k, _ = R_new.shape
    N = state.capacity
    slot0 = state.n_active
    Rf = R_new.astype(state.ratings.dtype)
    ratings = jax.lax.dynamic_update_slice(state.ratings, Rf,
                                           (slot0, jnp.int32(0)))
    new_norms = jax.vmap(jnp.linalg.norm)(R_new.astype(jnp.float32))
    norms = jax.lax.dynamic_update_slice(state.norms, new_norms, (slot0,))

    # One (k, m) x (m, N) fused-epilogue matmul instead of k matvecs.
    S = cosine_similarity(R_new.astype(jnp.float32), ratings,
                          new_norms, norms, interpret=interpret)
    cols = jnp.arange(N, dtype=jnp.int32)[None, :]
    seen = slot0 + jnp.arange(k, dtype=jnp.int32)[:, None]
    S = jnp.where(cols < seen, S, SENTINEL)              # per-step active set
    idx = jnp.argsort(S, axis=1).astype(jnp.int32)
    vals = jnp.take_along_axis(S, idx, axis=1)

    return CFState(
        ratings=ratings,
        norms=norms,
        sim_vals=jax.lax.dynamic_update_slice(
            state.sim_vals, vals.astype(state.sim_vals.dtype),
            (slot0, jnp.int32(0))),
        sim_idx=jax.lax.dynamic_update_slice(state.sim_idx, idx,
                                             (slot0, jnp.int32(0))),
        n_active=state.n_active + k,
    )
