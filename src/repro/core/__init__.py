"""The paper's contribution: TwinSearch new-user onboarding for
neighbourhood-based collaborative filtering, plus the CF substrate it lives
in (similarity measures, sorted lists, kNN prediction, incremental updates,
list maintenance)."""
from repro.core.types import (CFState, OnboardStats, TwinResult, SENTINEL,
                              SENTINEL_GATE, active_mask, set0_cap)
from repro.core.similarity import (cosine_matrix, cosine_vs_all,
                                   pearson_matrix, adjusted_cosine_matrix,
                                   similarity_matrix, row_norms)
from repro.core.knn import (build_state, sort_rows, top_k_neighbors,
                            top_k_neighbors_batch, predict,
                            predict_from_neighbors, predict_batch, recommend,
                            recommend_from_neighbors, recommend_batch)
from repro.core.baseline import (build_list, append_user, onboard_traditional,
                                 onboard_batch_traditional)
from repro.core.twinsearch import (twinsearch_find, onboard_twinsearch,
                                   onboard_batch, make_probes, probe_sims,
                                   candidate_mask, verify_candidates)
from repro.core.maintenance import (insert_into_lists,
                                    insert_batch_into_lists,
                                    merge_new_users_into_base, splice_twin,
                                    splice_twins, twin_sims_block)
from repro.core.rotation import (RotationPlan, rotate_arena,
                                 rotate_arena_frozen, unsorted_rows)

__all__ = [
    "CFState", "OnboardStats", "TwinResult", "SENTINEL", "SENTINEL_GATE",
    "active_mask", "set0_cap", "cosine_matrix", "cosine_vs_all",
    "pearson_matrix", "adjusted_cosine_matrix", "similarity_matrix",
    "row_norms", "build_state", "sort_rows", "top_k_neighbors",
    "top_k_neighbors_batch", "predict", "predict_from_neighbors",
    "predict_batch", "recommend", "recommend_from_neighbors",
    "recommend_batch", "build_list", "append_user", "onboard_traditional",
    "onboard_batch_traditional", "twinsearch_find", "onboard_twinsearch",
    "onboard_batch", "make_probes", "probe_sims", "candidate_mask",
    "verify_candidates", "insert_into_lists", "insert_batch_into_lists",
    "merge_new_users_into_base", "splice_twin", "splice_twins",
    "twin_sims_block", "RotationPlan", "rotate_arena",
    "rotate_arena_frozen", "unsorted_rows",
]
