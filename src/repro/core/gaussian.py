"""The paper's |Set_0| analysis (Sec 3.2, Eq. 3-4) + an exact re-derivation.

The paper models any user's similarity-list values as Gaussian with support
[0, 1] ⊂ [μ−4σ, μ+4σ], partitions [0, 1] into x equal sub-lists, and bounds
|Set_0| by the largest sub-list's mass:

    s = (Φ(k3) + Φ(k4) − 1) / (Φ(k1) + Φ(k2) − 1) · n          (Eq. 3)

maximised subject to μ−k1σ=0, μ+k2σ=1, μ−k3σ=0, μ+k4σ=1/x, 0≤k≤4 (Eq. 4).
The paper states the optimum k1=k3=0, k2=4, k4=0.01 giving s = n/125.

Note (recorded for EXPERIMENTS.md): the stated optimum is internally
inconsistent — k1=0, k2=4 forces μ=0, σ=1/4, under which μ+k4σ=1/x with
x=100 gives k4=0.04 (s = n/31), not k4=0.01.  Taking the paper's k-values at
face value reproduces n/125; ``exact_bound`` evaluates Eq. 3 consistently for
any (μ, σ, x) and ``empirical_max_sublist`` measures the real quantity on
data.  The framework's static candidate cap keeps the paper's n/125 with a
slack factor, plus an overflow-checked fallback, so either reading is safe.
"""
from __future__ import annotations

import numpy as np
from scipy.stats import norm


def paper_fraction() -> float:
    """Eq. 3 evaluated at the paper's stated optimum (k1=k3=0, k2=4,
    k4=0.01) — the 1/125 constant."""
    k1, k2, k3, k4 = 0.0, 4.0, 0.0, 0.01
    return (norm.cdf(k3) + norm.cdf(k4) - 1) / (norm.cdf(k1) + norm.cdf(k2) - 1)


def paper_bound(n: int) -> float:
    return paper_fraction() * n


def exact_fraction(mu: float, sigma: float, x: int = 100) -> float:
    """Largest sub-list mass fraction for an actual N(mu, sigma) truncated to
    [0, 1], partitioned into x equal-width sub-lists (consistent Eq. 3)."""
    total = norm.cdf((1 - mu) / sigma) - norm.cdf((0 - mu) / sigma)
    if total <= 0:
        return 0.0
    edges = np.linspace(0.0, 1.0, x + 1)
    mass = norm.cdf((edges[1:] - mu) / sigma) - norm.cdf((edges[:-1] - mu) / sigma)
    return float(mass.max() / total)


def exact_bound(n: int, mu: float, sigma: float, x: int = 100) -> float:
    return exact_fraction(mu, sigma, x) * n


def empirical_max_sublist(sim_row: np.ndarray, x: int = 100) -> int:
    """Measured largest sub-list size of one user's similarity list."""
    vals = np.asarray(sim_row, dtype=np.float64)
    vals = vals[(vals >= 0.0) & (vals <= 1.0)]
    hist, _ = np.histogram(vals, bins=x, range=(0.0, 1.0))
    return int(hist.max())


def empirical_set0(sim_rows: np.ndarray, sims0: np.ndarray,
                   tol: float) -> int:
    """Measured |Set_0| for given probe rows/values — the quantity the static
    cap must dominate."""
    masks = np.abs(sim_rows - sims0[:, None]) <= tol
    return int(np.all(masks, axis=0).sum())
