"""Similarity measures for neighbourhood-based CF.

All measures are exposed in two forms:
  * ``*_matrix(R)``  — full pairwise similarity (the O(n^2 m) build);
  * ``*_vs_all(R, norms, r0)`` — one new row against every existing row (the
    O(n m) traditional per-user path the paper's TwinSearch displaces).

Zero entries mean "unrated".  Cosine (the paper's benchmark metric) reduces
to normalised matmuls, which is also what the Pallas kernel in
``repro/kernels/similarity`` implements; Pearson over the co-rated support is
expressed exactly with four matmuls so it stays MXU-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def row_norms(R: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(jnp.square(R.astype(jnp.float32)), axis=-1))


def _safe(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, EPS)


# ---------------------------------------------------------------------------
# Cosine (the paper's metric)
# ---------------------------------------------------------------------------

def cosine_matrix(R: jax.Array, *, compute_dtype=jnp.float32) -> jax.Array:
    """(n, n) cosine similarity; fp32 accumulation."""
    Rn = R.astype(compute_dtype) / _safe(row_norms(R))[:, None].astype(compute_dtype)
    return jnp.einsum("im,jm->ij", Rn, Rn,
                      preferred_element_type=jnp.float32)


def cosine_vs_all(R: jax.Array, norms: jax.Array, r0: jax.Array) -> jax.Array:
    """(n,) cosine similarity of one new row ``r0`` against every row of R.

    ``norms`` is the cached row-norm vector (0 for inactive rows: their
    similarity is reported as 0 and must be masked by the caller).
    """
    r0 = r0.astype(jnp.float32)
    dots = jnp.einsum("nm,m->n", R.astype(jnp.float32), r0,
                      preferred_element_type=jnp.float32)
    denom = _safe(norms) * _safe(jnp.linalg.norm(r0))
    return dots / denom


# ---------------------------------------------------------------------------
# Pearson over the co-rated support (exact, matmul form)
# ---------------------------------------------------------------------------

def pearson_matrix(R: jax.Array) -> jax.Array:
    """Pearson correlation restricted to co-rated items, computed exactly via
    matmuls:  with B = (R != 0),

      n_co      = B  @ B.T
      sum_uv    = R  @ R.T          (non-co terms vanish: 0 * r = 0)
      sum_u|v   = R  @ B.T          (row sums over the co-support)
      sq_u|v    = R^2 @ B.T

      cov  = sum_uv - sum_u * sum_v / n_co
      var_u = sq_u - sum_u^2 / n_co   (and symmetrically for v)
    """
    Rf = R.astype(jnp.float32)
    B = (Rf != 0).astype(jnp.float32)
    n_co = B @ B.T
    sum_uv = Rf @ Rf.T
    sum_u = Rf @ B.T               # sum of u's ratings over co-support with v
    sq_u = jnp.square(Rf) @ B.T
    n_safe = _safe(n_co)
    cov = sum_uv - sum_u * sum_u.T / n_safe
    var_u = sq_u - jnp.square(sum_u) / n_safe
    var_v = var_u.T
    sim = cov / _safe(jnp.sqrt(_safe(var_u) * _safe(var_v)))
    # Pairs with < 2 co-rated items carry no signal.
    return jnp.where(n_co >= 2, sim, 0.0)


def adjusted_cosine_matrix(R: jax.Array) -> jax.Array:
    """Item-based adjusted cosine: centre each *user's* ratings by their mean
    before the item-item cosine (Sarwar et al. 2001).  Expects R as
    (items, users): centring runs along axis 0 of the transpose layout."""
    Rf = R.astype(jnp.float32)
    B = (Rf != 0)
    user_sum = jnp.sum(Rf, axis=0)
    user_cnt = _safe(jnp.sum(B, axis=0).astype(jnp.float32))
    centred = jnp.where(B, Rf - (user_sum / user_cnt)[None, :], 0.0)
    return cosine_matrix(centred)


MEASURES = {
    "cosine": cosine_matrix,
    "pearson": pearson_matrix,
    "adjusted_cosine": adjusted_cosine_matrix,
}


def similarity_matrix(R: jax.Array, measure: str = "cosine") -> jax.Array:
    try:
        fn = MEASURES[measure]
    except KeyError:
        raise ValueError(f"unknown similarity measure {measure!r}; "
                         f"have {sorted(MEASURES)}")
    return fn(R)
