"""Sorted similarity lists + kNN rating prediction.

The per-user sorted similarity list is the core data structure of
neighbourhood CF (and of the paper's algorithm, which binary-searches it).
Lists are stored ascending so ``jnp.searchsorted`` applies directly; the
"top" of a list is its tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL, SENTINEL_GATE, active_mask
from repro.core.similarity import row_norms, similarity_matrix


def sort_rows(S: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort each row ascending; returns (vals, idx) with idx int32."""
    idx = jnp.argsort(S, axis=-1).astype(jnp.int32)
    vals = jnp.take_along_axis(S, idx, axis=-1)
    return vals, idx


def build_state(R: jax.Array, *, capacity_extra: int = 0,
                measure: str = "cosine") -> CFState:
    """Full similarity build: the traditional O(n^2 m) path, producing the
    sorted lists the system maintains thereafter.  ``capacity_extra``
    preallocates slots for onboarding bursts."""
    n, m = R.shape
    N = n + capacity_extra
    Rf = R.astype(jnp.float32)
    S = similarity_matrix(Rf, measure)

    if capacity_extra:
        pad = jnp.full((n, capacity_extra), SENTINEL, S.dtype)
        S = jnp.concatenate([S, pad], axis=1)
        S = jnp.concatenate([S, jnp.full((capacity_extra, N), SENTINEL,
                                         S.dtype)], axis=0)
        Rf = jnp.concatenate([Rf, jnp.zeros((capacity_extra, m), Rf.dtype)],
                             axis=0)
    vals, idx = sort_rows(S)
    return CFState(
        ratings=Rf,
        norms=row_norms(Rf),
        sim_vals=vals,
        sim_idx=idx,
        n_active=jnp.asarray(n, jnp.int32),
    )


def top_k_neighbors(state: CFState, user: jax.Array, k: int
                    ) -> tuple[jax.Array, jax.Array]:
    """(k,) highest-similarity neighbours of ``user`` (excluding self),
    from the sorted list tail.

    Slots past the real neighbour count (``k > n_active - 1`` on a
    half-empty arena) carry SENTINEL similarity and are clamped to
    neighbour 0, so downstream gathers stay in-bounds and weight them
    zero — they never contribute to a prediction.  Entries whose index
    points at an inactive arena row are masked out entirely: a rotated or
    partially-filled arena may hold stale-looking values in dead slots.
    """
    vals = state.sim_vals[user]
    idx = state.sim_idx[user]
    not_self = idx != user
    live = idx < state.n_active
    ranked = jnp.where(not_self & live & (vals > SENTINEL_GATE), vals,
                       SENTINEL)
    kk = min(k, ranked.shape[0])
    top_vals, pos = jax.lax.top_k(ranked, kk)
    nbrs = idx[pos]
    if kk < k:                      # k beyond capacity: pad with dead slots
        top_vals = jnp.concatenate(
            [top_vals, jnp.full((k - kk,), SENTINEL, top_vals.dtype)])
        nbrs = jnp.concatenate([nbrs, jnp.zeros((k - kk,), nbrs.dtype)])
    nbrs = jnp.where(top_vals > SENTINEL_GATE, nbrs, 0)
    return top_vals, nbrs


def predict_from_neighbors(state: CFState, sims: jax.Array,
                           nbrs: jax.Array, item: jax.Array) -> jax.Array:
    """Scoring half of ``predict``: weighted average over a precomputed
    (k,) neighbour list (SENTINEL-similarity slots weigh zero)."""
    r = state.ratings[nbrs, item]
    w = jnp.where((r != 0) & (sims > 0), sims, 0.0)
    denom = jnp.sum(jnp.abs(w))
    return jnp.where(denom > 0, jnp.sum(w * r) / jnp.maximum(denom, 1e-12),
                     0.0)


def predict(state: CFState, user: jax.Array, item: jax.Array, k: int = 20
            ) -> jax.Array:
    """kNN weighted-average rating prediction r̂(u, i) =
    Σ_v sim(u,v)·r(v,i) / Σ_v |sim(u,v)| over the top-k neighbours of u that
    rated i."""
    sims, nbrs = top_k_neighbors(state, user, k)
    return predict_from_neighbors(state, sims, nbrs, item)


def recommend_from_neighbors(state: CFState, user: jax.Array,
                             sims: jax.Array, nbrs: jax.Array,
                             n_rec: int = 10
                             ) -> tuple[jax.Array, jax.Array]:
    """Scoring half of ``recommend``: neighbour-weighted item scores from a
    precomputed (k,) neighbour list, seen items masked to -inf."""
    w = jnp.maximum(sims, 0.0)
    nbr_ratings = state.ratings[nbrs]                      # (k, m)
    rated_mask = (nbr_ratings != 0).astype(jnp.float32)
    scores = jnp.einsum("k,km->m", w, nbr_ratings)
    denom = jnp.einsum("k,km->m", w, rated_mask)
    scores = scores / jnp.maximum(denom, 1e-12)
    scores = jnp.where(state.ratings[user] != 0, -jnp.inf, scores)
    return jax.lax.top_k(scores, n_rec)


def recommend(state: CFState, user: jax.Array, k_neighbors: int = 20,
              n_rec: int = 10) -> tuple[jax.Array, jax.Array]:
    """Top-``n_rec`` unseen items for ``user`` by neighbour-weighted score."""
    sims, nbrs = top_k_neighbors(state, user, k_neighbors)
    return recommend_from_neighbors(state, user, sims, nbrs, n_rec)


# ---------------------------------------------------------------------------
# Batched query path — one dispatch, one host transfer per batch
# ---------------------------------------------------------------------------

def top_k_neighbors_batch(state: CFState, users: jax.Array, k: int
                          ) -> tuple[jax.Array, jax.Array]:
    """(B,) users -> ((B, k) sims, (B, k) neighbour ids), vmapped."""
    return jax.vmap(lambda u: top_k_neighbors(state, u, k))(users)


def predict_batch(state: CFState, users: jax.Array, items: jax.Array,
                  k: int = 20) -> jax.Array:
    """(B,) users x (B,) items -> (B,) predictions.  Row b is bit-identical
    to ``predict(state, users[b], items[b], k)`` — the batch is a vmap of
    the scalar path, not a re-derivation."""
    return jax.vmap(lambda u, i: predict(state, u, i, k))(users, items)


def recommend_batch(state: CFState, users: jax.Array,
                    k_neighbors: int = 20, n_rec: int = 10
                    ) -> tuple[jax.Array, jax.Array]:
    """(B,) users -> ((B, n_rec) scores, (B, n_rec) items), row-wise
    bit-identical to the scalar ``recommend``."""
    return jax.vmap(lambda u: recommend(state, u, k_neighbors, n_rec))(users)
