"""Distributed TwinSearch under ``shard_map`` — the web-scale serving path.

GSPMD cannot partition dynamic row lookups (probe-list fetches, twin-row
copies) on the row-sharded (N, N) similarity store: it falls back to
"involuntary full rematerialization", replicating the whole arena
(measured 8TB/device temp at web scale — §Perf Cell C).  Here every
distributed access is explicit and intrinsic-cost:

  * probe rows / twin rows: masked local ``dynamic_slice`` + ``psum``
    (exactly one row of traffic per fetch);
  * candidate verification: **shard-local** — each shard gathers only its
    own candidate rows (a local HBM read) and contributes one bool per
    candidate; cross-device traffic for the paper's O(|Set_0|·m) term is
    ~s_max bits;
  * the traditional fallback: local matvec + one tiled ``all_gather``;
  * the burst accumulates in a replicated (k, N+k) write buffer; the base
    arena is never written (LSM-style, merged offline).

Per-user collective bytes ≈ (c+2)·N·4 — independent of m, ~3 orders below
the GSPMD formulation at the Douban scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import shard_map

from repro.core.types import CFState, OnboardStats, SENTINEL


def _shard_id(axes: tuple[str, ...], sizes: dict[str, int]) -> jax.Array:
    sid = jnp.int32(0)
    for a in axes:
        sid = sid * sizes[a] + jax.lax.axis_index(a)
    return sid


def onboard_batch_sharded(state: CFState, R_new: jax.Array,
                          probe_idx: jax.Array, *, s_max: int,
                          axes: tuple[str, ...], mesh, tol: float = 1e-6,
                          unroll: bool = False, maintain: bool = False):
    """state arrays row-sharded P(axes, ...); returns (vals, idx, stats)
    for the k new users, lists over N_base + k entries (ascending).

    ``maintain=True`` appends a fourth element (base_vals, base_idx): the
    row-sharded (N_base, N_base + k) base lists with the whole burst
    merged in.  The k-way merge-insert is row-local — each shard merges
    only its own rows, reading its slice of the replicated write buffer —
    so batched maintenance adds **zero** collective traffic on top of the
    onboarding scan (vs k full shift-gather passes sequentially).
    """
    N_base = state.capacity
    k, m = R_new.shape
    N_tot = N_base + k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_shards = 1
    for a in axes:
        n_shards *= sizes[a]
    rows_loc = N_base // n_shards
    s_loc = min(s_max, rows_loc)

    Rn_new = R_new.astype(jnp.float32)
    new_norms = jnp.sqrt(jnp.sum(jnp.square(Rn_new), axis=1))
    karange = jnp.arange(k, dtype=jnp.int32)

    def local(ratings, norms, sim_vals, sim_idx, R_new_, probes_):
        sid = _shard_id(axes, sizes)
        offset = sid * rows_loc

        def fetch(arr, g, width):
            """Replicated row ``g`` of a row-sharded (rows_loc, width)."""
            r = jnp.clip(g - offset, 0, rows_loc - 1)
            row = jax.lax.dynamic_slice(arr, (r, 0), (1, width))[0]
            mine = (g >= offset) & (g < offset + rows_loc)
            return jax.lax.psum(jnp.where(mine, row, 0), axes)

        def step(carry, inp):
            buf, j = carry
            r0, probes = inp
            r0f = r0.astype(jnp.float32)
            r0n = jnp.maximum(jnp.linalg.norm(r0f), 1e-12)

            # --- probe sims: dot on the owning shard, psum scalars -----
            def one_probe(p):
                r = jnp.clip(p - offset, 0, rows_loc - 1)
                row = jax.lax.dynamic_slice(ratings, (r, 0), (1, m))[0]
                nrm = jax.lax.dynamic_slice(norms, (r,), (1,))[0]
                mine = (p >= offset) & (p < offset + rows_loc)
                d = jnp.dot(row.astype(jnp.float32), r0f)
                d = d / (jnp.maximum(nrm, 1e-12) * r0n)
                return jnp.where(mine, d, 0.0)
            sims0 = jax.lax.psum(jax.vmap(one_probe)(probes), axes)  # (c,)

            # --- equal-range search + mask intersect (replicated) ------
            rows_v = jax.vmap(lambda p: fetch(sim_vals, p, N_base))(probes)
            rows_i = jax.vmap(lambda p: fetch(
                sim_idx.astype(jnp.float32), p, N_base))(probes).astype(
                jnp.int32)
            lo = jax.vmap(lambda row, s: jnp.searchsorted(
                row, s, side="left"))(rows_v, sims0 - tol)
            hi = jax.vmap(lambda row, s: jnp.searchsorted(
                row, s, side="right"))(rows_v, sims0 + tol)
            pos = jnp.arange(N_base, dtype=jnp.int32)[None, :]
            in_range = (pos >= lo[:, None]) & (pos < hi[:, None])
            c = probes.shape[0]
            umask = jnp.zeros((c, N_base), bool).at[
                jnp.arange(c)[:, None], rows_i].set(in_range)
            umask = umask.at[jnp.arange(c), probes].max(
                jnp.abs(sims0 - 1.0) <= tol)
            cand = jnp.all(umask, axis=0)                # (N_base,) repl.

            # --- shard-local verification ------------------------------
            mask_loc = jax.lax.dynamic_slice(cand, (offset,), (rows_loc,))
            n_cand = jax.lax.psum(jnp.sum(mask_loc, dtype=jnp.int32), axes)
            _, lidx = jax.lax.top_k(mask_loc.astype(jnp.float32), s_loc)
            lvalid = mask_loc[lidx]
            lrows = ratings[lidx]                        # local HBM gather
            leq = jnp.all(lrows == r0.astype(lrows.dtype)[None, :],
                          axis=1) & lvalid
            found_b_loc = jnp.any(leq)
            best_loc = jnp.where(found_b_loc,
                                 offset + lidx[jnp.argmax(leq)], -1)
            found_b = jax.lax.psum(found_b_loc.astype(jnp.int32), axes) > 0
            twin_b = jax.lax.pmax(best_loc, axes)
            overflow = jax.lax.psum(
                (jnp.sum(mask_loc, dtype=jnp.int32) > s_loc).astype(
                    jnp.int32), axes) > 0

            # --- burst-internal twins (replicated, no state reads) ------
            live = karange < j
            eq_new = jnp.all(R_new_ == r0[None, :], axis=1) & live
            found_n = jnp.any(eq_new)
            twin_n = jnp.argmax(eq_new).astype(jnp.int32)

            bsims = jnp.einsum("km,m->k", Rn_new, r0f) / (
                jnp.maximum(new_norms, 1e-12) * r0n)
            bsims = jnp.where(live, bsims, SENTINEL)

            # --- row construction: copy / copy-new / fallback ----------
            def fallback(_):
                d_loc = jnp.einsum("nm,m->n", ratings.astype(jnp.float32),
                                   r0f)
                s_loc_v = d_loc / (jnp.maximum(norms, 1e-12) * r0n)
                return jax.lax.all_gather(s_loc_v, axes, axis=0,
                                          tiled=True)

            def copy_base(_):
                tvals = fetch(sim_vals, twin_b, N_base)
                tidx = fetch(sim_idx.astype(jnp.float32), twin_b,
                             N_base).astype(jnp.int32)
                u = jnp.full((N_base,), SENTINEL, jnp.float32)
                return u.at[tidx].set(tvals)

            def copy_new(_):
                return buf[twin_n, :N_base]

            branch = jnp.where(found_b, 1, jnp.where(found_n, 2, 0))
            base_row = jax.lax.switch(branch,
                                      [fallback, copy_base, copy_new],
                                      None)
            row = jnp.concatenate([base_row, bsims])
            buf = jax.lax.dynamic_update_index_in_dim(buf, row, j, axis=0)
            found = found_b | found_n
            twin = jnp.where(found_b, twin_b, N_base + twin_n)
            return (buf, j + 1), (found, twin, n_cand, overflow)

        buf0 = jnp.full((k, N_tot), SENTINEL, jnp.float32)
        (buf, _), outs = jax.lax.scan(step, (buf0, jnp.int32(0)),
                                      (R_new_, probes_),
                                      unroll=k if unroll else 1)
        idx = jnp.argsort(buf, axis=1).astype(jnp.int32)
        vals = jnp.take_along_axis(buf, idx, axis=1)
        if not maintain:
            return vals, idx, outs
        # Shard-local batched maintenance: merge the burst into this
        # shard's (rows_loc, N_base) lists, fed by the local column slice
        # of the replicated write buffer.  No collectives.
        sid = _shard_id(axes, sizes)
        sims_loc = jax.lax.dynamic_slice(buf, (0, sid * rows_loc),
                                         (k, rows_loc))
        from repro.core.maintenance import merge_new_users_into_base
        m_vals, m_idx = merge_new_users_into_base(
            sim_vals, sim_idx, sims_loc,
            N_base + jnp.arange(k, dtype=jnp.int32), use_pallas=False)
        return vals, idx, outs, (m_vals, m_idx)

    rows = P(axes, None)
    out_specs = (P(None, None), P(None, None),
                 (P(None), P(None), P(None), P(None)))
    if maintain:
        out_specs = out_specs + ((rows, rows),)
    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(rows, P(axes), rows, rows, P(None, None), P(None, None)),
        out_specs=out_specs,
        check_vma=False,
    )(state.ratings, state.norms, state.sim_vals, state.sim_idx, R_new,
      probe_idx)
    vals, idx, (found, twin, ncand, ovf) = out[:3]
    stats = OnboardStats(found=found, twin_idx=twin, n_candidates=ncand,
                         overflowed=ovf)
    if maintain:
        return vals, idx, stats, out[3]
    return vals, idx, stats


def onboard_batch_resilient(state: CFState, R_new: jax.Array,
                            probe_idx: jax.Array, *, s_max: int,
                            axes: tuple[str, ...], mesh,
                            replicas=None, retry=None, tol: float = 1e-6,
                            unroll: bool = False, maintain: bool = False):
    """``onboard_batch_sharded`` behind the serving resilience layer.

    Pre-flight, the replicated arena (``distributed/replication.py``)
    sweeps replica health and heals any poisoned primary rows from
    surviving replicas — pure data movement, so a dead shard's garbage
    never feeds the scan.  The shard_map launch itself runs under the
    serving ``RetryPolicy`` (transient executor faults retry with
    backoff).  Returns ``(state, result)``: ``state`` is the (possibly
    healed) arena the scan actually ran on.

    Raises ``RuntimeError`` if a poisoned row has no surviving replica —
    at that point only a snapshot rollback (the serving layer's job) can
    help, and running the scan over garbage would waste the collective
    traffic.
    """
    from repro.serving import guard as _guard       # no import cycle: lazy

    if replicas is not None:
        replicas.sweep()
        fixed, rows = replicas.repair(state)
        if fixed is None:
            raise RuntimeError(
                f"{rows.size} arena rows unrecoverable (all replicas of "
                f"their shard down); roll back to a snapshot")
        state = fixed

    def run():
        out = onboard_batch_sharded(state, R_new, probe_idx, s_max=s_max,
                                    axes=axes, mesh=mesh, tol=tol,
                                    unroll=unroll, maintain=maintain)
        jax.block_until_ready(out)
        return out

    result, _retries = _guard.call_with_retry(
        run, retry or _guard.RetryPolicy())
    return state, result
