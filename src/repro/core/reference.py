"""Pure-NumPy transcription of Algorithm 1 — the testing oracle.

This mirrors the paper's pointer/set formulation (binary search on sorted
lists, Python-set intersection, early-exit verification loop) so the
vectorised JAX implementation in ``twinsearch.py`` can be property-tested
against it.
"""
from __future__ import annotations

import bisect

import numpy as np


def cosine_vs_all_np(R: np.ndarray, r0: np.ndarray) -> np.ndarray:
    norms = np.linalg.norm(R.astype(np.float64), axis=1)
    n0 = np.linalg.norm(r0.astype(np.float64))
    dots = R.astype(np.float64) @ r0.astype(np.float64)
    return dots / np.maximum(norms * max(n0, 1e-12), 1e-12)


def build_sorted_lists_np(R: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Full similarity build; ascending per row, (vals, idx)."""
    Rf = R.astype(np.float64)
    norms = np.maximum(np.linalg.norm(Rf, axis=1), 1e-12)
    S = (Rf / norms[:, None]) @ (Rf / norms[:, None]).T
    idx = np.argsort(S, axis=1, kind="stable").astype(np.int32)
    vals = np.take_along_axis(S, idx, axis=1)
    return vals, idx


def twinsearch_np(R: np.ndarray, sim_vals: np.ndarray, sim_idx: np.ndarray,
                  r0: np.ndarray, probes: np.ndarray, tol: float = 1e-6
                  ) -> tuple[bool, int, set[int]]:
    """Algorithm 1 on NumPy/python structures.

    Returns (found, twin_index, Set_0).  ``sim_vals``/``sim_idx`` are the
    ascending sorted lists of the *existing* n users.
    """
    n = R.shape[0]
    sims0 = cosine_vs_all_np(R, r0)[probes]

    sets: list[set[int]] = []
    for i, p in enumerate(probes):
        row_v = sim_vals[p]
        row_i = sim_idx[p]
        lo = bisect.bisect_left(row_v.tolist(), sims0[i] - tol)
        hi = bisect.bisect_right(row_v.tolist(), sims0[i] + tol)
        s = set(int(x) for x in row_i[lo:hi])
        if abs(sims0[i] - 1.0) <= tol:          # lines 5-7
            s.add(int(p))
        sets.append(s)

    set0 = sets[0]
    for s in sets[1:]:
        set0 &= s

    for x in sorted(set0):                       # lines 10-15
        if np.array_equal(R[x], r0.astype(R.dtype)):
            return True, x, set0
    return False, -1, set0
