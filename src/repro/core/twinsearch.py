"""TwinSearch (Lu & Shen 2015, Algorithm 1) — TPU-native JAX implementation.

Finds an existing *twin* (identical rating row) of a new user u0 and copies
the twin's similarity list instead of recomputing it:

  1. probe:      sim(u0, u_i*) for c random probe users          O(c·m)
  2. search:     equal-range ``searchsorted`` pair in each probe's
                 ascending sorted list                            O(c·log n)
  3. intersect:  candidate bitmasks AND-reduced                   O(c·n)
  4. verify:     exact rating-row equality on ≤ s_max gathered
                 candidates (s_max = the paper's n/125 Gaussian
                 bound, made a static shape)                      O(s_max·m)
  5. copy:       gather the twin's (vals, idx) row                O(n)

Hardware adaptation vs the paper's pointer/set version (DESIGN.md §3):
equal ranges are tolerance-parameterised float intervals; the set
intersection is a vectorised mask-AND; verification is a batched masked
reduce instead of an early-exit loop; the probabilistic |Set_0| bound becomes
the static candidate-gather shape with an overflow-checked fallback.

The onboarding burst also always verifies against the "new block" (rows
appended after ``n_base``): the paper's k identical users find their twin
among each other without requiring O(n) sorted-list maintenance of the whole
base population per insert (see DESIGN.md §3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import baseline
from repro.core.similarity import cosine_vs_all
from repro.core.types import (CFState, OnboardStats, SENTINEL_GATE,
                              TwinResult, set0_cap)


def probe_sims(state: CFState, r0: jax.Array, probe_idx: jax.Array
               ) -> jax.Array:
    """sim(u0, probe_i) for each of the c probes — O(c·m)."""
    Rp = state.ratings[probe_idx]                       # (c, m)
    return cosine_vs_all(Rp, state.norms[probe_idx], r0)


def candidate_mask(state: CFState, probe_idx: jax.Array, sims0: jax.Array,
                   tol: float) -> jax.Array:
    """(N,) bool — Set_0 = ∩_i { x : |sim(i, x) − sim(i, 0)| ≤ tol }.

    Equal ranges come from a ``searchsorted`` pair on each probe's ascending
    sorted list (the paper's binary search); the per-probe sets are
    materialised as bitmasks scattered through the sorted-order permutation
    and AND-reduced.  The fused Pallas kernel in ``repro/kernels/twin_probe``
    computes the same mask without materialising the (c, N) intermediate.
    """
    N = state.capacity
    rows_v = state.sim_vals[probe_idx]                  # (c, N) ascending
    rows_i = state.sim_idx[probe_idx]                   # (c, N)
    lo = jax.vmap(lambda row, s: jnp.searchsorted(row, s, side="left"))(
        rows_v, sims0 - tol)
    hi = jax.vmap(lambda row, s: jnp.searchsorted(row, s, side="right"))(
        rows_v, sims0 + tol)
    pos = jnp.arange(N, dtype=jnp.int32)[None, :]
    in_range = (pos >= lo[:, None]) & (pos < hi[:, None])   # sorted order
    c = probe_idx.shape[0]
    user_mask = jnp.zeros((c, N), bool).at[
        jnp.arange(c, dtype=jnp.int32)[:, None], rows_i].set(in_range)
    # Alg. 1 lines 5-7: a probe with sim(0, i) == 1 is itself a candidate.
    # (Its own self-entry already satisfies the range check; set explicitly
    # so the guarantee is independent of stored-value bit patterns.)
    self_is_cand = jnp.abs(sims0 - 1.0) <= tol
    user_mask = user_mask.at[jnp.arange(c), probe_idx].max(self_is_cand)
    return jnp.all(user_mask, axis=0)


def verify_candidates(state: CFState, r0: jax.Array, cand: jax.Array,
                      s_max: int, n_base: int, k_cap: int,
                      rows_spec=None
                      ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Gather ≤ s_max candidate rows (+ the ≤ k_cap new-block rows) and test
    exact rating equality.  Returns (found, twin_idx, n_cand, overflowed).

    ``rows_spec`` (optional PartitionSpec) shards the gathered candidate
    rows across devices so each shard verifies only its slice — without it
    GSPMD replicates the (s_max, m) gather on every device (§Perf Cell C).
    """
    N = state.capacity
    arange = jnp.arange(N, dtype=jnp.int32)
    active = arange < state.n_active
    cand = cand & active
    n_cand = jnp.sum(cand, dtype=jnp.int32)
    overflowed = n_cand > s_max

    # Static-shape candidate gather: top_k on the mask is stable, so we get
    # the s_max lowest-indexed candidates (and detect truncation above).
    _, cidx = jax.lax.top_k(cand.astype(jnp.float32), s_max)
    cidx = cidx.astype(jnp.int32)
    valid = cand[cidx]

    if k_cap > 0:
        # Always verify the onboarding block (rows n_base..n_base+k_cap):
        # the paper's k identical users twin *each other*.
        block = n_base + jnp.arange(k_cap, dtype=jnp.int32)
        block = jnp.minimum(block, N - 1)
        bvalid = (n_base + jnp.arange(k_cap, dtype=jnp.int32)) < state.n_active
        cidx = jnp.concatenate([cidx, block])
        valid = jnp.concatenate([valid, bvalid])

    rows = state.ratings[cidx]                           # (s_max+k_cap, m)
    if rows_spec is not None:
        rows = jax.lax.with_sharding_constraint(rows, rows_spec)
    eq = jnp.all(rows == r0.astype(rows.dtype)[None, :], axis=1) & valid
    found = jnp.any(eq)
    twin_idx = cidx[jnp.argmax(eq)]
    return found, twin_idx, n_cand, overflowed


@partial(jax.jit, static_argnames=("s_max", "n_base", "k_cap", "tol",
                                   "rows_spec"))
def twinsearch_find(state: CFState, r0: jax.Array, probe_idx: jax.Array,
                    *, s_max: int, n_base: int = 0, k_cap: int = 0,
                    tol: float = 1e-6, rows_spec=None) -> TwinResult:
    """Algorithm 1, lines 1-15: find a verified twin of ``r0`` (no copy)."""
    sims0 = probe_sims(state, r0, probe_idx)
    cand = candidate_mask(state, probe_idx, sims0, tol)
    found, twin_idx, n_cand, overflowed = verify_candidates(
        state, r0, cand, s_max, n_base, k_cap, rows_spec)
    return TwinResult(found=found, twin_idx=twin_idx, n_candidates=n_cand,
                      overflowed=overflowed, probe_sims=sims0)


def onboard_twinsearch(state: CFState, r0: jax.Array, probe_idx: jax.Array,
                       *, s_max: int, n_base: int = 0, k_cap: int = 0,
                       tol: float = 1e-6, rows_spec=None
                       ) -> tuple[CFState, TwinResult]:
    """One new user through TwinSearch with traditional fallback.

    If a twin verifies, its similarity row is copied — O(n) — and the entries
    for the onboarding block (users added after the twin's list was built,
    which the copied row cannot contain) are recomputed at O(k·m) and patched
    in, so the copied list is *exactly* what a traditional build would
    produce.  Otherwise — including not-found-and-overflowed, where the
    static candidate cap may have truncated Set_0 — the traditional O(n·m)
    build runs.  Both paths end in the same O(n log n) sort, which is
    sub-dominant either way (the paper's win is avoiding the O(n·m) matvec).
    """
    res = twinsearch_find(state, r0, probe_idx, s_max=s_max, n_base=n_base,
                          k_cap=k_cap, tol=tol, rows_spec=rows_spec)
    N = state.capacity
    from repro.core.types import SENTINEL, active_mask

    def copy_path(_):
        # Reconstruct the twin's *unsorted* similarity row by scattering its
        # sorted list through its permutation — O(n), no similarity compute.
        tvals = state.sim_vals[res.twin_idx]
        tidx = state.sim_idx[res.twin_idx]
        u = jnp.full((N,), SENTINEL, state.sim_vals.dtype)
        u = u.at[tidx].set(tvals)
        if k_cap > 0:
            # Patch the onboarding block with fresh sims — O(k·m).
            block = jnp.minimum(n_base + jnp.arange(k_cap, dtype=jnp.int32),
                                N - 1)
            bsims = cosine_vs_all(state.ratings[block], state.norms[block],
                                  r0)
            u = u.at[block].set(bsims.astype(u.dtype))
        return jnp.where(active_mask(state), u, SENTINEL)

    def build_path(_):
        sims = cosine_vs_all(state.ratings, state.norms, r0)
        return jnp.where(active_mask(state), sims, SENTINEL)

    sims_row = jax.lax.cond(res.found, copy_path, build_path, operand=None)
    idx = jnp.argsort(sims_row).astype(jnp.int32)
    vals = jnp.take_along_axis(sims_row, idx, axis=-1)
    return baseline.append_user(state, r0, vals, idx), res


def onboard_batch(state: CFState, R_new: jax.Array, probe_idx: jax.Array,
                  *, s_max: int | None = None, tol: float = 1e-6,
                  set0_divisor: int = 125, set0_slack: float = 1.5,
                  unroll: bool = False, rows_spec=None
                  ) -> tuple[CFState, OnboardStats]:
    """k new users via TwinSearch — the paper's O((1 + (k−1)/125)·m·n) path.

    ``R_new``: (k, m); ``probe_idx``: (k, c) precomputed random probes.
    ``n_base`` is the live count at entry; the whole burst (k rows) is the
    always-verified new block.
    """
    k, _ = R_new.shape
    n_base = int(state.capacity - k)     # capacity was sized n + k
    if s_max is None:
        s_max = set0_cap(n_base, set0_divisor, set0_slack)

    def step(st, inp):
        r0, probes = inp
        st, res = onboard_twinsearch(st, r0, probes, s_max=s_max,
                                     n_base=n_base, k_cap=k, tol=tol,
                                     rows_spec=rows_spec)
        return st, (res.found, res.twin_idx, res.n_candidates,
                    res.overflowed)

    state, (found, twin, ncand, ovf) = jax.lax.scan(
        step, state, (R_new, probe_idx), unroll=k if unroll else 1)
    return state, OnboardStats(found=found, twin_idx=twin,
                               n_candidates=ncand, overflowed=ovf)


def make_probes(key: jax.Array, k: int, c: int, n_base: int) -> jax.Array:
    """(k, c) random probe indices over the base population (line 1)."""
    return jax.random.randint(key, (k, c), 0, n_base, dtype=jnp.int32)


def onboard_batch_buffered(state: CFState, R_new: jax.Array,
                           probe_idx: jax.Array, *, s_max: int,
                           tol: float = 1e-6, unroll: bool = False,
                           rows_spec=None, maintain: bool = False,
                           use_pallas: bool | None = None):
    """Distributed onboarding burst over an **immutable** base state.

    The mutable-arena variant (``onboard_batch``) dynamic-updates rows of
    the row-sharded (N, N) similarity store at a traced index inside the
    scan; under GSPMD that lowers to full-array masked selects — measured
    8TB/device of temp at web scale (§Perf Cell C).  Production stores land
    new users in a small write buffer instead (merged into the arena
    asynchronously); this implements exactly that:

      * the base state (ratings, sorted lists) is read-only;
      * the burst's rows accumulate **unsorted** in a (k, N_base + k)
        buffer (new-block entries included, sentinel for not-yet-added);
      * burst-internal twins verify directly against ``R_new`` (no state
        reads at all);
      * all k rows sort once, vectorised, at the end.

    Returns (vals (k, N_tot) ascending, idx (k, N_tot), stats); with
    ``maintain=True`` a fourth element (base_vals, base_idx) — every base
    row's list re-sorted to width N_tot with all k new users merged in by
    one fused k-way merge-insert (``repro/kernels/list_merge``), fed
    directly from the write buffer's base columns at zero extra similarity
    compute.  This is the batched replacement for k sequential
    ``insert_into_lists`` passes: O(N·(N + k)) instead of k·O(N²).
    """
    N_base = state.capacity
    k, m = R_new.shape
    N_tot = N_base + k
    from repro.core.types import SENTINEL

    Rn = R_new.astype(jnp.float32)
    new_norms = jnp.sqrt(jnp.sum(jnp.square(Rn), axis=1))
    karange = jnp.arange(k, dtype=jnp.int32)

    def step(carry, inp):
        buf, j = carry                          # (k, N_tot) f32, () int32
        r0, probes = inp
        sims0 = probe_sims(state, r0, probes)
        cand = candidate_mask(state, probes, sims0, tol)
        found_b, twin_b, n_cand, ovf = verify_candidates(
            state, r0, cand, s_max, 0, 0, rows_spec)

        # Burst-internal twins: verify against R_new directly.
        live = karange < j
        eq_new = jnp.all(R_new == r0[None, :], axis=1) & live
        found_n = jnp.any(eq_new)
        twin_n = jnp.argmax(eq_new).astype(jnp.int32)

        # Block sims are needed on every path (the copied row must carry
        # entries for previously-added burst users) — O(k·m).
        bsims = cosine_vs_all(Rn, new_norms, r0.astype(jnp.float32))
        bsims = jnp.where(live, bsims, SENTINEL)

        def fallback(_):
            return cosine_vs_all(state.ratings, state.norms, r0)

        def copy_base(_):
            u = jnp.full((N_base,), SENTINEL, jnp.float32)
            return u.at[state.sim_idx[twin_b]].set(
                state.sim_vals[twin_b].astype(jnp.float32))

        def copy_new(_):
            return buf[twin_n, :N_base]

        branch = jnp.where(found_b, 1, jnp.where(found_n, 2, 0))
        base_row = jax.lax.switch(branch, [fallback, copy_base, copy_new],
                                  None)
        row = jnp.concatenate([base_row, bsims])
        buf = jax.lax.dynamic_update_index_in_dim(buf, row, j, axis=0)
        found = found_b | found_n
        twin = jnp.where(found_b, twin_b, N_base + twin_n)
        return (buf, j + 1), (found, twin, n_cand, ovf)

    buf0 = jnp.full((k, N_tot), SENTINEL, jnp.float32)
    (buf, _), (found, twin, ncand, ovf) = jax.lax.scan(
        step, (buf0, jnp.int32(0)), (R_new, probe_idx),
        unroll=k if unroll else 1)

    idx = jnp.argsort(buf, axis=1).astype(jnp.int32)
    vals = jnp.take_along_axis(buf, idx, axis=1)
    stats = OnboardStats(found=found, twin_idx=twin, n_candidates=ncand,
                         overflowed=ovf)
    if not maintain:
        return vals, idx, stats
    from repro.core.maintenance import merge_new_users_into_base
    maintained = merge_new_users_into_base(
        state.sim_vals, state.sim_idx, buf[:, :N_base],
        N_base + karange, use_pallas=use_pallas)
    return vals, idx, stats, maintained
