"""Incremental similarity maintenance for *existing* users.

This is the related-work path (Papagelis et al., ISMIS'05) the paper
contrasts with: when an existing user adds/changes a rating, the cached
dot-products let the affected similarity row refresh in O(n + n log n)
instead of an O(n m) rebuild.  TwinSearch covers the complementary case
(new users with duplicate rows); a production system runs both.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import CFState, SENTINEL, active_mask


class SimCache(NamedTuple):
    dots: jax.Array      # (N, N) cached R @ R.T
    sq: jax.Array        # (N,)   cached ||r_u||^2


def init_cache(ratings: jax.Array) -> SimCache:
    Rf = ratings.astype(jnp.float32)
    return SimCache(dots=Rf @ Rf.T, sq=jnp.sum(jnp.square(Rf), axis=1))


def add_rating(state: CFState, cache: SimCache, user: jax.Array,
               item: jax.Array, rating: jax.Array
               ) -> tuple[CFState, SimCache]:
    """User ``user`` sets item ``item`` to ``rating`` (0 removes).

    Incremental identities (e = r_new − r_old on coordinate ``item``):
      dots[u, v] += e · R[v, item]      ∀v        — O(n)
      sq[u]      += r_new² − r_old²
    then only row u of the sorted lists re-sorts — O(n log n).
    """
    Rf = state.ratings
    r_old = Rf[user, item]
    e = rating.astype(jnp.float32) - r_old.astype(jnp.float32)

    col = Rf[:, item].astype(jnp.float32)
    new_dots_row = cache.dots[user] + e * col
    # The u-u self dot also gains e·r_old from the column term; fix exactly:
    self_dot = cache.sq[user] + 2 * r_old * e + e * e
    new_dots_row = new_dots_row.at[user].set(self_dot)
    dots = cache.dots.at[user].set(new_dots_row).at[:, user].set(new_dots_row)
    sq = cache.sq.at[user].set(self_dot)

    ratings = Rf.at[user, item].set(rating.astype(Rf.dtype))
    norms = state.norms.at[user].set(jnp.sqrt(self_dot))

    denom = jnp.maximum(jnp.sqrt(self_dot) * jnp.maximum(
        jnp.sqrt(sq), 1e-12), 1e-12)
    sims = new_dots_row / denom
    sims = jnp.where(active_mask(state), sims, SENTINEL)
    idx = jnp.argsort(sims).astype(jnp.int32)
    vals = jnp.take_along_axis(sims, idx, axis=-1)

    new_state = CFState(
        ratings=ratings,
        norms=norms,
        sim_vals=state.sim_vals.at[user].set(vals),
        sim_idx=state.sim_idx.at[user].set(idx),
        n_active=state.n_active,
    )
    return new_state, SimCache(dots=dots, sq=sq)
