"""Twin detection over graph adjacency — the paper's idea transplanted.

A node's neighbour list is structurally a user's similarity list; nodes with
identical adjacency rows ("structural twins") produce identical GNN messages
and can share computation.  Used by the molecule pipeline to dedup
isomorphic-featured nodes; exposed as a generic utility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adjacency_signature(edge_dst: jax.Array, edge_src: jax.Array,
                        n_nodes: int, n_hash: int = 4) -> jax.Array:
    """(n_nodes, n_hash) order-invariant signatures of each node's neighbour
    multiset via summed multiplicative hashes of neighbour ids."""
    primes = jnp.asarray([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F][
        :n_hash], jnp.uint32)
    h = (edge_src.astype(jnp.uint32)[:, None] * primes[None, :]) ^ (
        edge_src.astype(jnp.uint32)[:, None] >> 7)
    sig = jnp.zeros((n_nodes, primes.shape[0]), jnp.uint32)
    return sig.at[edge_dst].add(h)


def twin_groups(signatures: jax.Array) -> jax.Array:
    """(n,) group id per node; nodes sharing a signature share a group.
    Collisions are resolved by the caller via exact row comparison (the same
    probe-then-verify structure as TwinSearch)."""
    n = signatures.shape[0]
    packed = signatures.astype(jnp.uint64)
    key = packed[:, 0]
    for j in range(1, signatures.shape[1]):
        key = key * jnp.uint64(0x100000001B3) + packed[:, j]
    order = jnp.argsort(key)
    sorted_key = key[order]
    new_group = jnp.concatenate([jnp.array([True]),
                                 sorted_key[1:] != sorted_key[:-1]])
    gid_sorted = jnp.cumsum(new_group) - 1
    gid = jnp.zeros(n, gid_sorted.dtype).at[order].set(gid_sorted)
    return gid
