"""Core state container for the neighbourhood-CF system.

The system state is a fixed-capacity pytree so every maintenance operation
(new-user onboarding, rating updates) is jit-able with static shapes:

  * ``ratings``  — (N, m) dense rating matrix, 0 = unrated.  Row i is user i
                   (user-based mode) or item i (item-based mode runs the same
                   code on the transpose).
  * ``norms``    — (N,) cached L2 row norms (0 for inactive rows).
  * ``sim_vals`` — (N, N) per-row similarity lists sorted **ascending**
                   (top-neighbour = tail).  Inactive entries hold SENTINEL so
                   they sort to the head and never collide with real
                   similarities in [-1, 1].
  * ``sim_idx``  — (N, N) int32: ``sim_vals[i, j]`` is the similarity between
                   user i and user ``sim_idx[i, j]``.
  * ``n_active`` — () int32 count of live rows; rows [n_active, N) are the
                   preallocated slots new users are appended into.

Capacity N = n_base + k_cap where k_cap bounds the onboarding burst size
(the paper's k identical new users).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

SENTINEL = jnp.float32(-2.0)
# Anything above this is a real similarity (cosine/pearson live in [-1, 1]).
SENTINEL_GATE = -1.5


class CFState(NamedTuple):
    ratings: jax.Array          # (N, m)
    norms: jax.Array            # (N,)
    sim_vals: jax.Array         # (N, N) ascending per row
    sim_idx: jax.Array          # (N, N) int32
    n_active: jax.Array         # () int32

    @property
    def capacity(self) -> int:
        return self.ratings.shape[0]

    @property
    def n_items(self) -> int:
        return self.ratings.shape[1]


class TwinResult(NamedTuple):
    """Outcome of one TwinSearch probe-and-verify pass."""

    found: jax.Array            # () bool — a verified twin exists
    twin_idx: jax.Array         # () int32 — index of the twin (garbage if !found)
    n_candidates: jax.Array     # () int32 — |Set_0| before the static cap
    overflowed: jax.Array       # () bool — |Set_0| exceeded the static bound
    probe_sims: jax.Array       # (c,) — sims between the new user and probes


class OnboardStats(NamedTuple):
    """Per-new-user statistics from a batched onboarding scan."""

    found: jax.Array            # (k,) bool
    twin_idx: jax.Array         # (k,) int32
    n_candidates: jax.Array     # (k,) int32
    overflowed: jax.Array       # (k,) bool


def active_mask(state: CFState) -> jax.Array:
    """(N,) bool — which capacity rows hold live users."""
    return jnp.arange(state.capacity, dtype=jnp.int32) < state.n_active


def set0_cap(n: int, divisor: int = 125, slack: float = 1.5,
             minimum: int = 8) -> int:
    """Static candidate-set bound from the paper's Gaussian analysis.

    The paper (Sec 3.2) bounds |Set_0| by n/125; ``slack`` absorbs tie mass
    the Gaussian model under-counts on small/quantised datasets.  This bound
    becomes the *shape* of the candidate gather, turning the paper's
    probabilistic argument into the compiled program's contract.
    """
    import math
    cap = max(minimum, int(math.ceil(n / divisor * slack)))
    if cap > 512:
        # round to the shard boundary so the candidate gather can shard
        # evenly over every mesh axis (see verify rows_spec)
        cap = -(-cap // 512) * 512
    return cap
