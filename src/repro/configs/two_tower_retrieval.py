"""Two-tower retrieval (YouTube / Yi et al. RecSys'19): embed_dim=256,
tower MLP 1024-512-256, dot-product interaction, in-batch sampled softmax
with logQ correction. [RecSys'19 (YouTube); unverified]

User tower: user-id + context fields; item tower: item-id + item fields.
This is the architecture the paper's TwinSearch technique attaches to: the
serving layer maintains per-user sorted similarity lists over tower
embeddings (see repro/serving/cf_server.py).
"""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register
from repro.configs._fields import powerlaw_vocabs

CONFIG = RecsysConfig(
    name="two-tower-retrieval",
    variant="two_tower",
    embed_dim=256,
    tower_mlp=(1024, 512, 256),
    user_vocab=50_000_000,
    item_vocab=10_000_000,
    field_vocab_sizes=powerlaw_vocabs(6, largest=100_000, smallest=16,
                                      n_large=2),
    n_dense=0,
)

SPEC = register(ArchSpec(
    arch_id="two-tower-retrieval",
    family="recsys",
    config=CONFIG,
    shapes=RECSYS_SHAPES,
    source="RecSys'19 (YouTube); unverified",
))
