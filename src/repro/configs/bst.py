"""BST (Behavior Sequence Transformer, Alibaba): embed_dim=32, seq_len=20,
1 transformer block, 8 heads, MLP 1024-512-256. [arXiv:1905.06874; paper]

User behaviour sequence (item ids + positions) + target item through one
transformer block; concatenated with "other features" embeddings into the
MLP -> CTR logit.  Item vocabulary 4M (Taobao-scale); 8 side-feature fields.
"""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register
from repro.configs._fields import powerlaw_vocabs

CONFIG = RecsysConfig(
    name="bst",
    variant="bst",
    embed_dim=32,
    item_vocab=4_000_000,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp_dims=(1024, 512, 256),
    field_vocab_sizes=powerlaw_vocabs(8, largest=1_000_000, smallest=8,
                                      n_large=2),
    n_dense=0,
)

SPEC = register(ArchSpec(
    arch_id="bst",
    family="recsys",
    config=CONFIG,
    shapes=RECSYS_SHAPES,
    source="arXiv:1905.06874; paper",
))
