from repro.configs.base import (ArchSpec, CFConfig, GNNConfig, LMConfig,
                                MoEConfig, RecsysConfig, ShapeSpec, get_arch,
                                list_archs, register)

__all__ = [
    "ArchSpec", "CFConfig", "GNNConfig", "LMConfig", "MoEConfig",
    "RecsysConfig", "ShapeSpec", "get_arch", "list_archs", "register",
]
