"""OLMoE-1B-7B: 16L d_model=2048 16H (MHA kv=16) d_ff=1024/expert, 64e top-8.

[arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924]
1B active / 7B total parameters; no shared expert; full attention.
"""
from repro.configs.base import (ArchSpec, LMConfig, MoEConfig, LM_SHAPES,
                                FULL_ATTN_LONG_SKIP, register)

CONFIG = LMConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50_304,
    act="swiglu",
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024, n_shared=0),
    rope_theta=10_000.0,
    tie_embeddings=False,
)

SPEC = register(ArchSpec(
    arch_id="olmoe-1b-7b",
    family="lm",
    config=CONFIG,
    shapes=LM_SHAPES,
    source="arXiv:2409.02060; hf",
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
))
