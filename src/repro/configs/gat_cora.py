"""GAT (Cora config): 2 layers, 8 hidden units x 8 heads, attention aggregator.

[arXiv:1710.10903; paper] First layer 8 heads x 8 units concatenated (ELU),
second layer 1 output head (n_classes) for full-graph transductive cells;
the sampled / batched cells reuse the same layer config.
"""
from repro.configs.base import ArchSpec, GNNConfig, GNN_SHAPES, register

CONFIG = GNNConfig(
    name="gat-cora",
    n_layers=2,
    d_hidden=8,
    n_heads=8,
    aggregator="attn",
    n_classes=7,
)

SPEC = register(ArchSpec(
    arch_id="gat-cora",
    family="gnn",
    config=CONFIG,
    shapes=GNN_SHAPES,
    source="arXiv:1710.10903; paper",
))
