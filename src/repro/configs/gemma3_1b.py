"""Gemma-3-1B: 26L d_model=1152 4H (MQA kv=1) head_dim=256 d_ff=6912
vocab=262144; 5:1 local:global sliding window (512), 32k context on 1b.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, register

CONFIG = LMConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    act="geglu",
    window=512,
    global_every=6,          # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
)

SPEC = register(ArchSpec(
    arch_id="gemma3-1b",
    family="lm",
    config=CONFIG,
    shapes=LM_SHAPES,
    source="hf:google/gemma-3-1b-pt; unverified",
    notes="long_500k runs: 5/6 layers are 512-window local; global-layer KV "
          "shards over the model axis.",
))
