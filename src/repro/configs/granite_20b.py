"""Granite-20B-Code: 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

[arXiv:2405.04324; hf:ibm-granite/granite-20b-code-base]
GPT-BigCode-style body (MQA kv=1, dense 4x GELU MLP). The assignment labels it
"llama-arch"; the published checkpoint uses MQA + dense GELU MLP, which the
kv=1 and d_ff=4*d here corroborate, so that is what we implement. Learned
absolute positions in the checkpoint are replaced by RoPE so the 32k decode
cells are well-defined (deviation recorded in DESIGN.md).
"""
from repro.configs.base import (ArchSpec, LMConfig, LM_SHAPES,
                                FULL_ATTN_LONG_SKIP, register)

CONFIG = LMConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SPEC = register(ArchSpec(
    arch_id="granite-20b",
    family="lm",
    config=CONFIG,
    shapes=LM_SHAPES,
    source="arXiv:2405.04324; hf",
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
))
