"""AutoInt: 39 sparse fields, embed_dim=16, 3 self-attn layers, 2 heads,
d_attn=32. [arXiv:1810.11921; paper]
Multi-head self-attention over field embeddings with residual connections;
final layer concatenates all field outputs into the CTR logit.
"""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register
from repro.configs._fields import CRITEO39

CONFIG = RecsysConfig(
    name="autoint",
    variant="autoint",
    embed_dim=16,
    field_vocab_sizes=CRITEO39,
    n_dense=13,
    n_attn_layers=3,
    n_attn_heads=2,
    d_attn=32,
)

SPEC = register(ArchSpec(
    arch_id="autoint",
    family="recsys",
    config=CONFIG,
    shapes=RECSYS_SHAPES,
    source="arXiv:1810.11921; paper",
))
