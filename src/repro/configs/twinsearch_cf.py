"""The paper's own system: neighbourhood-based CF with TwinSearch new-user
onboarding.  [Lu & Shen 2015, cs.IR]

Shapes mirror the paper's two datasets (MovieLens-100k 943x1682, Douban
129,490x58,541) plus a web-scale onboarding cell that exercises the
distributed path at 1M users. c=8 probes; the static candidate bound is the
paper's n/125 Gaussian bound with 1.5x slack.
"""
from repro.configs.base import ArchSpec, CFConfig, CF_SHAPES, register

CONFIG = CFConfig(
    name="twinsearch-cf",
    mode="user",
    similarity="cosine",
    c_probes=8,
    set0_divisor=125,
    set0_slack=1.5,
    sim_tol=0.0,
)

SPEC = register(ArchSpec(
    arch_id="twinsearch-cf",
    family="cf",
    config=CONFIG,
    shapes=CF_SHAPES,
    source="Lu & Shen 2015 (the reproduced paper)",
    notes="Extra arch beyond the 40 assigned cells; hosts the paper's "
          "technique and its benchmarks.",
))
