"""Config dataclasses + registry for all selectable architectures.

Every assigned architecture gets one module in ``repro/configs/<id>.py`` that
instantiates an :class:`ArchSpec` with the exact published configuration and
its assigned input-shape set.  The registry maps ``--arch <id>`` to the spec.

Families:
  * ``lm``      — decoder-only transformers (dense + MoE).
  * ``gnn``     — message-passing GNNs (GAT).
  * ``recsys``  — CTR / retrieval models over sparse embedding tables.
  * ``cf``      — the paper's own neighbourhood-CF system (TwinSearch).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping


# ---------------------------------------------------------------------------
# Shape specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell of the (arch x shape) matrix.

    ``kind`` selects which step function is lowered:
      * lm:     ``train`` -> train_step, ``prefill`` -> prefill_step,
                ``decode`` -> serve_step (1 new token against a KV cache).
      * gnn:    ``train_full`` / ``train_sampled`` / ``train_batched``.
      * recsys: ``train`` / ``serve`` / ``retrieval``.
      * cf:     ``build`` (full similarity build) / ``onboard`` (TwinSearch).

    ``skip`` holds a human-readable reason when a cell is skipped for an
    architecture (e.g. long-context decode on a pure full-attention model).
    """

    name: str
    kind: str
    dims: Mapping[str, Any] = field(default_factory=dict)

    def dim(self, key: str, default: Any = None) -> Any:
        return self.dims.get(key, default)


# ---------------------------------------------------------------------------
# Per-family model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # shared (always-on) experts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    act: str = "swiglu"                 # swiglu | geglu | gelu
    moe: MoEConfig | None = None
    # Attention pattern: window=None -> full attention everywhere.
    # window=W with global_every=G -> layers l where (l+1) % G == 0 are
    # global-attention, all others are sliding-window of size W
    # (Gemma-3's 5:1 local:global, Llama-4's 3:1 chunked:NoPE-global).
    window: int | None = None
    global_every: int | None = None
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    embed_scale: bool = False           # Gemma-style sqrt(d_model) embed scaling
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    # Activation sharding: shard the sequence axis of inter-block activations
    # over the model axis (Megatron sequence-parallel analogue under GSPMD).
    seq_shard: bool = True

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameter count (exact, matching init_params)."""
        d, L = self.d_model, self.n_layers
        embed = self.vocab_size * d
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.moe is not None:
            m = self.moe
            glu = 3 if self.act in ("swiglu", "geglu") else 2
            expert = glu * d * m.d_ff_expert
            ffn = m.n_experts * expert + m.n_shared * expert + d * m.n_experts
        else:
            glu = 3 if self.act in ("swiglu", "geglu") else 2
            ffn = glu * d * self.d_ff
        norms = 2 * d * L + d
        out = 0 if self.tie_embeddings else self.vocab_size * d
        return embed + L * (attn + ffn) + norms + out

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.n_layers
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        expert = glu * d * m.d_ff_expert
        dense_total = self.param_count() - L * (m.n_experts - 0) * expert
        return dense_total + L * (m.top_k) * expert


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    n_heads: int
    aggregator: str = "attn"            # GAT
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: str = "float32"


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    variant: str                        # bst | xdeepfm | autoint | two_tower
    embed_dim: int
    # Sparse feature layout: one concatenated table; vocab per field.
    field_vocab_sizes: tuple[int, ...] = ()
    n_dense: int = 0
    mlp_dims: tuple[int, ...] = ()
    # xDeepFM
    cin_layers: tuple[int, ...] = ()
    # AutoInt
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # BST
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    item_vocab: int = 0
    # two-tower
    tower_mlp: tuple[int, ...] = ()
    user_vocab: int = 0
    dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.field_vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.field_vocab_sizes))


@dataclass(frozen=True)
class CFConfig:
    """The paper's neighbourhood-CF system (sizes live in the ShapeSpec)."""

    name: str
    mode: str = "user"                   # user-based or item-based CF
    similarity: str = "cosine"
    c_probes: int = 8
    # Static candidate bound: ceil(n / set0_divisor) * slack. 125 is the
    # paper's Gaussian-analysis bound (Sec 3.2); slack absorbs ties.
    set0_divisor: int = 125
    set0_slack: float = 1.5
    sim_tol: float = 0.0
    dtype: str = "float32"


# ---------------------------------------------------------------------------
# ArchSpec + registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                          # lm | gnn | recsys | cf
    config: Any
    shapes: tuple[ShapeSpec, ...]
    source: str = ""
    notes: str = ""
    # shape name -> reason, for cells that must be skipped for this arch.
    skip_shapes: Mapping[str, str] = field(default_factory=dict)

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}; "
                       f"have {[s.name for s in self.shapes]}")

    def active_shapes(self) -> tuple[ShapeSpec, ...]:
        return tuple(s for s in self.shapes if s.name not in self.skip_shapes)


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    if spec.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {spec.arch_id}")
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}")


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

_CONFIG_MODULES = (
    "olmoe_1b_7b",
    "llama4_scout_17b_a16e",
    "gemma3_1b",
    "granite_20b",
    "gemma_7b",
    "gat_cora",
    "bst",
    "xdeepfm",
    "autoint",
    "two_tower_retrieval",
    "twinsearch_cf",
)


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


# ---------------------------------------------------------------------------
# Shared shape sets
# ---------------------------------------------------------------------------

LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4_096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32_768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32_768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524_288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train_full",
              {"n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433}),
    ShapeSpec("minibatch_lg", "train_sampled",
              {"n_nodes": 232_965, "n_edges": 114_615_892,
               "batch_nodes": 1_024, "fanout": (15, 10), "d_feat": 602}),
    ShapeSpec("ogb_products", "train_full",
              {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100}),
    ShapeSpec("molecule", "train_batched",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65_536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    ShapeSpec("retrieval_cand", "retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)

CF_SHAPES = (
    ShapeSpec("ml_build", "build", {"n_users": 943, "n_items": 1_682}),
    ShapeSpec("douban_build", "build", {"n_users": 129_490, "n_items": 58_541}),
    ShapeSpec("douban_onboard", "onboard",
              {"n_users": 129_490, "n_items": 58_541, "k_new": 30}),
    ShapeSpec("webscale_onboard", "onboard",
              {"n_users": 524_288, "n_items": 131_072, "k_new": 64}),
)

FULL_ATTN_LONG_SKIP = ("pure full attention: 500k-context cell assigned only "
                       "to sub-quadratic (local/chunked/SSM) architectures")


def pad_to_shard(n: int, multiple: int = 512) -> int:
    """Round a dimension up to the shard boundary (512 = max devices on the
    production meshes).  Tables / node stores / edge lists / similarity
    capacities pad to this so row-sharding over any axis subset divides
    evenly — the padding rows are dead weight (< 0.4%) masked by counts."""
    return -(-n // multiple) * multiple
