"""Gemma-7B: 28L d_model=3072 16H (MHA kv=16) head_dim=256 d_ff=24576
vocab=256000, GeGLU. [arXiv:2403.08295; hf:google/gemma-7b]
"""
from repro.configs.base import (ArchSpec, LMConfig, LM_SHAPES,
                                FULL_ATTN_LONG_SKIP, register)

CONFIG = LMConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab_size=256_000,
    act="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
)

SPEC = register(ArchSpec(
    arch_id="gemma-7b",
    family="lm",
    config=CONFIG,
    shapes=LM_SHAPES,
    source="arXiv:2403.08295; hf",
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
))
