"""xDeepFM: 39 sparse fields, embed_dim=10, CIN 200-200-200, deep MLP 400-400.

[arXiv:1803.05170; paper] Linear (wide) + CIN + DNN branches summed into the
CTR logit; CIN layer k: outer product of X^k with X^0 compressed by a 1x1
conv (H_{k+1} filters over H_k * F input channels).
"""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES, register
from repro.configs._fields import CRITEO39

CONFIG = RecsysConfig(
    name="xdeepfm",
    variant="xdeepfm",
    embed_dim=10,
    field_vocab_sizes=CRITEO39,
    n_dense=13,
    mlp_dims=(400, 400),
    cin_layers=(200, 200, 200),
)

SPEC = register(ArchSpec(
    arch_id="xdeepfm",
    family="recsys",
    config=CONFIG,
    shapes=RECSYS_SHAPES,
    source="arXiv:1803.05170; paper",
))
