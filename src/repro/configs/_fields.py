"""Deterministic sparse-field vocabulary layouts for the recsys configs.

Criteo-like field cardinalities span 10^1..10^7 with a heavy tail; the CTR
configs here (xDeepFM / AutoInt / BST) use a fixed power-law layout so every
run (tests, benches, dry-run) sees identical table geometry.
"""
from __future__ import annotations


def powerlaw_vocabs(n_fields: int, *, largest: int, smallest: int = 16,
                    n_large: int = 4) -> tuple[int, ...]:
    """``n_large`` hot fields at ``largest`` rows, rest geometric down to
    ``smallest``.  Deterministic; no RNG."""
    sizes = [largest] * n_large
    rest = n_fields - n_large
    if rest > 0:
        ratio = (smallest / largest) ** (1.0 / max(rest - 1, 1))
        val = largest * ratio
        for _ in range(rest):
            sizes.append(max(int(val), smallest))
            val *= ratio
    return tuple(sizes[:n_fields])


# 39 sparse fields, 4 x 10M hot fields, ~45.6M total rows.
CRITEO39 = powerlaw_vocabs(39, largest=10_000_000, smallest=16, n_large=4)

assert len(CRITEO39) == 39
