"""Llama-4-Scout-17B-16E: 48L d_model=5120 40H (GQA kv=8) expert d_ff=8192,
MoE 16e top-1 + 1 shared expert; chunked-local attention (8192-token chunks)
on 3 of every 4 layers with full (NoPE) attention on the 4th — iRoPE.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
17B active / 109B total. Early fusion (VLM frontend is out of scope here; the
LM backbone is what the assignment specifies).
"""
from repro.configs.base import (ArchSpec, LMConfig, MoEConfig, LM_SHAPES,
                                register)

CONFIG = LMConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    act="swiglu",
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
    # Chunked/local attention with window 8192 on local layers; every 4th
    # layer is global full-attention -> long_500k is sub-quadratic overall.
    window=8192,
    global_every=4,
    rope_theta=500_000.0,
    tie_embeddings=False,
)

SPEC = register(ArchSpec(
    arch_id="llama4-scout-17b-a16e",
    family="lm",
    config=CONFIG,
    shapes=LM_SHAPES,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    notes="long_500k runs: 3/4 layers chunked-local (8k window), KV for the "
          "global layers shards over the model axis.",
))
