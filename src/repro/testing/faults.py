"""Deterministic fault injection for the CF serving path.

Every fault a real fleet throws at the onboarding loop, reproducible from a
seed — no wall-clock sleeps, no flaky randomness:

  * **malformed requests** (``MalformedRequests``): NaN/Inf-poisoned rating
    vectors, truncated/over-long vectors, wrong dtypes, out-of-range
    values — everything ``serving/guard.py`` must refuse at the door;
  * **latency spikes** (``FakeClock`` + ``inject_latency``): the server's
    ``StragglerMonitor`` runs on an injectable clock; wrapping the jitted
    onboard callables advances that clock by a scripted schedule, so
    degradation-ladder transitions are exact, not timing-dependent;
  * **transient executor faults** (``Flaky``): a callable that raises for
    its first n invocations, exercising the retry/backoff/deadline path;
  * **state poisoning** (``poison_state``): NaNs written straight into the
    arena — bypassing the guard, as a bit-flip or a lost shard's garbage
    rows would — including whole shard-row-slice loss via
    ``distributed.sharding.shard_row_slice``;
  * **capacity floods** (``capacity_flood``): a scripted onboard burst far
    past ``capacity_extra``, forcing repeated arena rotations;
  * **process crashes** (``SimulatedCrash`` + ``install_crash``): kill the
    server at a named crash point in the WAL-ordered mutation flow
    (before/after the log append, after commit) — ``SimulatedCrash``
    derives from ``BaseException`` so it sails through every
    ``except Exception`` in the no-raise machinery, exactly like a real
    SIGKILL would;
  * **replica loss** (``kill_replica``): a node dies — its replica copies
    vanish (``ReplicatedArena.kill_node``) and the primary arena rows of
    its home shard turn to garbage — plus ``forbid_similarity_kernels``
    to prove recovery is pure data movement.

The harness mutates server-internal seams (``_onboard`` /
``_onboard_trad`` wrappers, direct ``state`` replacement) on purpose: the
point is to model faults *below* the validated request surface.
"""
from __future__ import annotations

from typing import Callable, Iterable, Sequence

import numpy as np

import jax.numpy as jnp

from repro.distributed.sharding import shard_row_slice


class FakeClock:
    """Monotonic virtual clock — pass ``clock=fake`` to StragglerMonitor /
    RetryPolicy and advance it from fault hooks."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


class Flaky:
    """Delegates to ``fn`` after raising for the first ``fail_times``
    calls — a transient executor fault."""

    def __init__(self, fn: Callable, fail_times: int,
                 exc: Exception | None = None):
        self.fn = fn
        self.remaining = int(fail_times)
        self.exc = exc or RuntimeError("injected transient fault")
        self.calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if self.remaining > 0:
            self.remaining -= 1
            raise self.exc
        return self.fn(*args, **kwargs)


class MalformedRequests:
    """Seeded factory of invalid rating vectors, one method per failure
    mode the guard must catch."""

    def __init__(self, n_items: int, seed: int = 0,
                 rating_range: tuple[float, float] = (1.0, 5.0)):
        self.m = int(n_items)
        self.rng = np.random.default_rng(seed)
        self.lo, self.hi = rating_range

    def _valid(self) -> np.ndarray:
        r = (self.rng.integers(int(self.lo), int(self.hi) + 1, self.m)
             * (self.rng.random(self.m) < 0.4)).astype(np.float32)
        r[0] = self.lo
        return r

    def nan_ratings(self) -> np.ndarray:
        r = self._valid()
        r[self.rng.integers(0, self.m, size=max(1, self.m // 8))] = np.nan
        return r

    def inf_ratings(self) -> np.ndarray:
        r = self._valid()
        r[self.rng.integers(0, self.m)] = np.inf
        return r

    def truncated(self) -> np.ndarray:
        return self._valid()[: self.m // 2]

    def overlong(self) -> np.ndarray:
        return np.concatenate([self._valid(), self._valid()])

    def wrong_dtype(self) -> np.ndarray:
        return np.array(["five"] * self.m, dtype=object)

    def out_of_range(self) -> np.ndarray:
        r = self._valid()
        r[self.rng.integers(0, self.m)] = self.hi * 100
        return r

    def all_zero(self) -> np.ndarray:
        return np.zeros(self.m, np.float32)

    def everything(self) -> list[tuple[str, np.ndarray]]:
        return [("nan", self.nan_ratings()), ("inf", self.inf_ratings()),
                ("truncated", self.truncated()),
                ("overlong", self.overlong()),
                ("wrong_dtype", self.wrong_dtype()),
                ("out_of_range", self.out_of_range()),
                ("all_zero", self.all_zero())]


def inject_latency(server, clock: FakeClock,
                   schedule: Sequence[float]) -> None:
    """Make the server's next onboard calls take scripted (virtual) time.

    Wraps both jitted onboard callables so call t advances ``clock`` by
    ``schedule[t]`` — the StragglerMonitor (constructed with this clock)
    sees exactly those step times.  Past the schedule's end the wrapper
    falls back to the final entry."""
    schedule = [float(s) for s in schedule]
    counter = {"i": 0}

    def wrap(fn):
        def wrapped(*args, **kwargs):
            i = min(counter["i"], len(schedule) - 1)
            counter["i"] += 1
            clock.advance(schedule[i])
            return fn(*args, **kwargs)
        return wrapped

    server._onboard = wrap(server._onboard)
    server._onboard_trad = wrap(server._onboard_trad)


def poison_state(server, *, rows: Iterable[int] | None = None,
                 shard: int | None = None, n_shards: int = 1,
                 field: str = "sim_vals") -> np.ndarray:
    """NaN-poison arena rows in place, bypassing the request guard —
    simulating memory corruption or shard loss.

    ``shard``/``n_shards`` selects the row-sharded slice a dead shard
    would stop serving (``distributed.sharding.shard_row_slice``);
    ``rows`` selects explicit rows.  Returns the poisoned row ids."""
    state = server.state
    arr = np.asarray(getattr(state, field)).copy()
    if shard is not None:
        sl = shard_row_slice(arr.shape[0], n_shards, shard)
        row_ids = np.arange(sl.start, sl.stop)
    else:
        row_ids = np.asarray(list(rows if rows is not None else [0]))
    arr[row_ids] = np.nan
    server.state = state._replace(**{field: jnp.asarray(arr)})
    return row_ids


def capacity_flood(server, pool: np.ndarray, n: int,
                   seed: int = 0) -> list[tuple[int, dict]]:
    """Onboard ``n`` users drawn deterministically from ``pool`` rows —
    sized to blow past ``capacity_extra`` and force rotations."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        out.append(server.onboard_user(pool[rng.integers(0, len(pool))]))
    return out


class SimulatedCrash(BaseException):
    """Process death at a crash point.  Deliberately NOT an ``Exception``:
    the serving layer's no-raise machinery (retry wrapper, onboard
    try/except) catches ``Exception`` only, so this propagates out of any
    entrypoint the way a SIGKILL ends a process mid-op."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at {point!r}")
        self.point = point


# The named points ``CFServer._crashpoint`` visits, in mutation-flow order.
CRASH_POINTS = ("onboard.pre_wal", "rotate.post_wal", "onboard.post_wal",
                "onboard.post_commit", "add_rating.pre_wal",
                "add_rating.post_wal", "add_rating.post_commit")

# Crash points inside an *incremental* rotation (rotation.budget_rows > 0):
# after a precompute slice (nothing logged — recovery must match the state
# at the crash), after the ``rotate_commit`` WAL append but before the
# swap applied (recovery must replay the swap), and after the swap.
ROTATION_CRASH_POINTS = ("rotation.step", "rotation.commit_post_wal",
                         "rotation.post_swap")


def install_crash(server, point: str, *, nth: int = 1) -> None:
    """Arm the server's crash hook: the ``nth`` time execution reaches the
    named crash point, raise ``SimulatedCrash``.  The server object is
    dead after that — recovery means building a NEW server with
    ``CFServer.recover(...)`` over the same ``wal_dir``/``snapshot_dir``."""
    remaining = {"n": int(nth)}

    def hook(name: str) -> None:
        if name == point:
            remaining["n"] -= 1
            if remaining["n"] <= 0:
                raise SimulatedCrash(point)

    server._crash_hook = hook


def kill_replica(server, node: int) -> np.ndarray:
    """Lose one node of the replicated arena: its replica copies are gone
    and the primary arena rows of its home shard (shard ``node`` under
    chained declustering) turn to garbage.  Returns the poisoned primary
    rows; the server must heal them from surviving replicas."""
    replicas = server.replicas
    assert replicas is not None, "server has no replication configured"
    replicas.kill_node(node)
    return poison_state(server, shard=node,
                        n_shards=replicas.cfg.n_shards)


def forbid_similarity_kernels(server) -> None:
    """Replace every similarity-computing callable on the server with a
    raiser — replica repair and re-replication must be pure data movement,
    and this makes any cheat raise immediately."""

    def boom(*_a, **_k):
        raise AssertionError("similarity kernel invoked during "
                             "replication recovery")

    server._onboard = boom
    server._onboard_trad = boom
    server._init_cache = boom
    server._add = boom
