"""Deterministic fault-injection tooling for resilience tests."""
from repro.testing.faults import (CRASH_POINTS, ROTATION_CRASH_POINTS,
                                 FakeClock, Flaky,
                                 MalformedRequests, SimulatedCrash,
                                 capacity_flood, forbid_similarity_kernels,
                                 inject_latency, install_crash,
                                 kill_replica, poison_state)

__all__ = ["CRASH_POINTS", "ROTATION_CRASH_POINTS", "FakeClock", "Flaky",
           "MalformedRequests",
           "SimulatedCrash", "capacity_flood", "forbid_similarity_kernels",
           "inject_latency", "install_crash", "kill_replica",
           "poison_state"]
