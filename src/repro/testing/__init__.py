"""Deterministic fault-injection tooling for resilience tests."""
from repro.testing.faults import (FakeClock, Flaky, MalformedRequests,
                                 capacity_flood, inject_latency,
                                 poison_state)

__all__ = ["FakeClock", "Flaky", "MalformedRequests", "capacity_flood",
           "inject_latency", "poison_state"]
