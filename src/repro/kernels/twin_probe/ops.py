"""Jit'd wrapper: pad N, run fused intersection, return (mask, count)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.twin_probe.kernel import twin_probe_pallas


@partial(jax.jit, static_argnames=("tol", "bn", "interpret"))
def twin_probe(probe_rows: jax.Array, sims0: jax.Array, *,
               tol: float = 1e-6, bn: int = 512,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(c, N) unsorted probe rows + (c,) probe sims -> Set_0 mask (N,) and
    |Set_0| count (the n/125 overflow check input)."""
    c, N = probe_rows.shape
    pad = (-N) % bn
    # Sentinel-pad so padded columns never match (sims live in [-1, 1]).
    rows = jnp.pad(probe_rows, ((0, 0), (0, pad)), constant_values=-3.0)
    mask, counts = twin_probe_pallas(rows, sims0, tol, bn=bn,
                                     interpret=interpret)
    return mask[:N, 0], jnp.sum(counts)
