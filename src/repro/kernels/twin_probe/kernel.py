"""Pallas TPU kernel: fused probe-interval intersection (Algorithm 1 line 9).

Given the c probes' similarity rows (user-id order) and the new user's
probe similarities, a user x is a Set_0 candidate iff
``|S[i, x] − s0_i| ≤ tol`` for every probe i.  The kernel streams (c, bn)
blocks through VMEM and emits both the AND-reduced candidate mask and a
per-block candidate count (the |Set_0| ≤ n/125 overflow check) in one pass
— the (c, N) boolean intermediate and the separate count reduction never
reach HBM.

c is small (the paper uses c ≪ n/125; we default 8) so the block working
set is c·bn·4 bytes ≈ 16 KB at bn=512.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _make_kernel(tol: float):
    def kernel(rows_ref, s0_ref, mask_ref, count_ref):
        blk = rows_ref[...]                              # (c, bn)
        s0 = s0_ref[...]                                 # (c, 1)
        hit = jnp.abs(blk - s0) <= tol
        mask = jnp.all(hit, axis=0)                      # (bn,)
        mask_ref[...] = mask[:, None]
        count_ref[...] = jnp.sum(mask.astype(jnp.int32))[None, None]
    return kernel


def twin_probe_pallas(probe_rows: jax.Array, sims0: jax.Array,
                      tol: float = 1e-6, *, bn: int = 512,
                      interpret: bool = True
                      ) -> tuple[jax.Array, jax.Array]:
    """probe_rows: (c, N) unsorted probe similarity rows; sims0: (c,).
    Returns (mask (N, 1) bool, per-block counts (N/bn, 1) int32)."""
    c, N = probe_rows.shape
    assert N % bn == 0, (N, bn)
    grid = (N // bn,)
    mask, counts = pl.pallas_call(
        _make_kernel(tol),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, bn), lambda j: (0, j)),
            pl.BlockSpec((c, 1), lambda j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bn, 1), lambda j: (j, 0)),
            pl.BlockSpec((1, 1), lambda j: (j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((N, 1), jnp.bool_),
            jax.ShapeDtypeStruct((N // bn, 1), jnp.int32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(probe_rows, sims0[:, None])
    return mask, counts
