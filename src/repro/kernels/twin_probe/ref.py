"""Pure-jnp oracle for the twin-probe intersection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def twin_probe_ref(probe_rows: jax.Array, sims0: jax.Array,
                   tol: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    hit = jnp.abs(probe_rows - sims0[:, None]) <= tol    # (c, N)
    mask = jnp.all(hit, axis=0)
    return mask, jnp.sum(mask.astype(jnp.int32))
