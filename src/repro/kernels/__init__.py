"""Pallas TPU kernels for the performance-critical hot spots.

Each kernel ships as kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with padding), and ref.py (pure-jnp
oracle the tests assert against in interpret mode).

  similarity/     blocked cosine-similarity matmul, fused norm epilogue
                  (the paper's traditional-path hot loop)
  twin_probe/     fused c-probe interval intersection + |Set_0| count
  verify_rows/    fused masked row-equality verification (Alg. 1 ll.10-15)
  embedding_bag/  scalar-prefetch row-gather bag sum (recsys substrate)
  list_merge/     fused k-way merge-insert for sorted-list maintenance
                  (burst-batched onboarding: k inserts, one arena pass)
  knn_score/      fused batched kNN recommendation scoring (the serving
                  read path: scalar-prefetch neighbour gather -> weighted
                  score -> normalise -> seen mask, item-tiled)
"""
from repro.kernels.similarity.ops import cosine_similarity
from repro.kernels.twin_probe.ops import twin_probe
from repro.kernels.verify_rows.ops import verify_rows
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.list_merge.ops import merge_insert
from repro.kernels.knn_score.ops import knn_scores, knn_recommend_topn

__all__ = ["cosine_similarity", "twin_probe", "verify_rows",
           "embedding_bag", "merge_insert", "knn_scores",
           "knn_recommend_topn"]
