"""Pallas TPU kernel: fused batched kNN recommendation scoring.

The serving read path's hot loop: for each (user, neighbour-list) row,
score every catalogue item by the positive-weighted average of the
neighbours' ratings, then mask already-seen items.  The einsum reference
first gathers a (B, k, m) neighbour-ratings block from HBM; at serving
scale (B=256, k=50, m=10^5) that intermediate alone is tens of GB.  Here
the gather never materialises: neighbour ids ride in scalar memory
(``PrefetchScalarGridSpec``, the ``embedding_bag`` idiom) and drive the
ratings BlockSpec index_map, so each grid step DMAs exactly the (1, bm)
row-slice it needs.

Grid is (B, m // bm, k) with the neighbour axis innermost: the weighted
score and rated-count accumulate in VMEM scratch across the k steps
(t == 0 initialises), and the epilogue at t == k - 1 normalises, applies
the seen-item mask from the user's own row (same ratings array, second
scalar-prefetched row gather), and writes the (1, bm) output block — one
HBM read per consumed element, one write per produced element.

Weight contract matches ``ref.py``: weights are pre-clamped ``>= 0`` and
a zero weight (SENTINEL / padded neighbour slot) is an exact no-op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.knn_score.ref import EPS


def _score_kernel(nbr_ref, u_ref, w_ref, r_ref, urow_ref, o_ref,
                  ssum_ref, dsum_ref, *, k: int):
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        ssum_ref[...] = jnp.zeros_like(ssum_ref)
        dsum_ref[...] = jnp.zeros_like(dsum_ref)

    r = r_ref[...]                                   # (1, bm) neighbour slice
    w = w_ref[b, t]
    ssum_ref[...] += w * r
    dsum_ref[...] += w * (r != 0).astype(jnp.float32)

    @pl.when(t == k - 1)
    def _epilogue():
        scores = ssum_ref[...] / jnp.maximum(dsum_ref[...], EPS)
        o_ref[...] = jnp.where(urow_ref[...] != 0, -jnp.inf, scores)


def knn_scores_pallas(ratings: jax.Array, w: jax.Array, nbrs: jax.Array,
                      users: jax.Array, *, bm: int = 512,
                      interpret: bool = True) -> jax.Array:
    """ratings: (N, mp) with mp % bm == 0; w: (B, k) f32 >= 0; nbrs: (B, k)
    int32 in [0, N); users: (B,) int32 in [0, N).  Returns (B, mp) scores
    with the querying user's rated items at -inf (see ``ref.py``)."""
    B, k = w.shape
    N, mp = ratings.shape
    assert mp % bm == 0, (ratings.shape, bm)
    assert nbrs.shape == (B, k) and users.shape == (B,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, mp // bm, k),
        in_specs=[
            pl.BlockSpec((1, bm), lambda b, j, t, nbr_ref, u_ref, w_ref:
                         (nbr_ref[b, t], j)),
            pl.BlockSpec((1, bm), lambda b, j, t, nbr_ref, u_ref, w_ref:
                         (u_ref[b], j)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda b, j, t, nbr_ref, u_ref,
                               w_ref: (b, j)),
        scratch_shapes=[
            pltpu.VMEM((1, bm), jnp.float32),
            pltpu.VMEM((1, bm), jnp.float32),
        ],
    )
    kernel = functools.partial(_score_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, mp), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(nbrs, users, w, ratings, ratings)
