"""Pure-jnp oracle for the fused batched kNN scoring kernel.

Semantics (shared by every backend): for a batch of B users, each with k
precomputed neighbours (``top_k_neighbors_batch``), score every item as
the positive-weighted average of the neighbours' ratings —

    score[b, j] = Σ_t w[b,t]·r(nbr[b,t], j) / max(Σ_t w[b,t]·[r≠0], eps)

— then mask items the user has already rated to -inf so a downstream
top-n only surfaces unseen items.  This is exactly the einsum logic of
the scalar ``core.knn.recommend`` lifted to a batch axis; the Pallas
kernel reproduces it without ever materialising the (B, k, m)
neighbour-ratings gather.

Weight contract: ``w`` is the already-clamped ``max(sims, 0)`` — a
SENTINEL (dead / padded) neighbour slot arrives as weight 0 and is an
exact no-op, the same gating mechanism ``list_merge`` uses for masked
insert lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def knn_scores_ref(ratings: jax.Array, w: jax.Array, nbrs: jax.Array,
                   users: jax.Array) -> jax.Array:
    """ratings: (N, m) arena; w: (B, k) non-negative neighbour weights;
    nbrs: (B, k) int32 neighbour rows; users: (B,) int32 querying users.
    Returns (B, m) float32 scores with seen items at -inf."""
    nbr_ratings = ratings[nbrs]                            # (B, k, m)
    rated_mask = (nbr_ratings != 0).astype(jnp.float32)
    scores = jnp.einsum("bk,bkm->bm", w, nbr_ratings)
    denom = jnp.einsum("bk,bkm->bm", w, rated_mask)
    scores = scores / jnp.maximum(denom, EPS)
    return jnp.where(ratings[users] != 0, -jnp.inf, scores)
