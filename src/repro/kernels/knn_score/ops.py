"""Jit'd public wrappers for the fused batched kNN scoring kernel.

``knn_scores`` routes to one of two equivalent backends:

  * ``use_pallas=True``  — the fused Pallas kernel (``kernel.py``;
    ``interpret=True`` executes it on CPU, pass False on a real TPU),
    which tiles the item axis and never materialises the (B, k, m)
    neighbour-ratings gather;
  * ``use_pallas=False`` — a ``lax.scan`` over the k neighbour slots
    that keeps only (B, m) accumulators live.  XLA's einsum of the
    (B, k, m) gather streams ~3x the bytes of the working set on CPU;
    the scan's per-step arrays stay cache-resident (3x faster at
    B=256, MovieLens shapes) while adding the k products in the same
    serial order, so it is element-identical to the ``ref.py`` einsum
    oracle (asserted in ``tests/test_kernels.py``).

``use_pallas=None`` (default) picks the Pallas kernel on TPU backends and
the einsum elsewhere — the same auto-selection ``list_merge`` uses.  Both
backends implement the value contract of ``ref.py`` (the Pallas kernel
accumulates the k-term sums serially, which is element-identical to the
einsum's sequential dot reduction on every grid the tests sweep; the
tolerance-tested bound in ``tests/test_kernels.py`` documents the
reduction-order ULP slack the contract permits).

``knn_recommend_topn`` appends the top-n cut — the full fused read path:
neighbour-gather -> positive-weighted score -> rated-mask normalise ->
seen-item mask -> top-n.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.knn_score.kernel import knn_scores_pallas
from repro.kernels.knn_score.ref import EPS


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _knn_scores_scan(ratings: jax.Array, w: jax.Array, nbrs: jax.Array,
                     users: jax.Array) -> jax.Array:
    """XLA fast path: accumulate the k weighted-neighbour terms with a
    scan so only two (B, m) accumulators are ever live — never the
    (B, k, m) gather.  Serial accumulation order == the einsum's dot
    reduction == the Pallas kernel's grid-t loop, so all three backends
    agree bitwise."""
    B, m = nbrs.shape[0], ratings.shape[1]
    zero = jnp.zeros((B, m), jnp.float32)

    def step(carry, t):
        ssum, dsum = carry
        rk = ratings[nbrs[:, t]]                       # (B, m) row gather
        wk = w[:, t][:, None]
        ssum = ssum + wk * rk
        dsum = dsum + wk * (rk != 0).astype(jnp.float32)
        return (ssum, dsum), None

    (scores, denom), _ = jax.lax.scan(
        step, (zero, zero), jnp.arange(nbrs.shape[1]))
    scores = scores / jnp.maximum(denom, EPS)
    return jnp.where(ratings[users] != 0, -jnp.inf, scores)


@partial(jax.jit, static_argnames=("use_pallas", "bm", "interpret"))
def knn_scores(ratings: jax.Array, w: jax.Array, nbrs: jax.Array,
               users: jax.Array, *, use_pallas: bool | None = None,
               bm: int = 512, interpret: bool = True) -> jax.Array:
    """Batched kNN item scores from precomputed neighbour lists.

    Args:
      ratings: (N, m) arena rating matrix (0 = unrated).
      w:       (B, k) non-negative neighbour weights (``max(sims, 0)``;
               zero-weight slots are exact no-ops).
      nbrs:    (B, k) int32 neighbour row ids.
      users:   (B,) int32 querying users (their rated items mask to -inf).

    Returns (B, m) float32 scores, seen items at -inf.
    """
    N, m = ratings.shape
    B, k = w.shape
    ratings = ratings.astype(jnp.float32)
    w = w.astype(jnp.float32)
    nbrs = jnp.clip(nbrs.astype(jnp.int32), 0, N - 1)
    users = jnp.clip(users.astype(jnp.int32), 0, N - 1)

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return _knn_scores_scan(ratings, w, nbrs, users)

    # Item columns pad to the block multiple with zeros: a padded column
    # scores 0/EPS = 0 and is never "seen", so it survives to the slice
    # below but no further (callers slice before any top-n).
    bm = min(bm, _round_up(m, 128))
    mp = _round_up(m, bm)
    rp = jnp.pad(ratings, ((0, 0), (0, mp - m)))
    out = knn_scores_pallas(rp, w, nbrs, users, bm=bm, interpret=interpret)
    return out[:, :m]


@partial(jax.jit, static_argnames=("n_rec", "use_pallas", "bm", "interpret"))
def knn_recommend_topn(ratings: jax.Array, w: jax.Array, nbrs: jax.Array,
                       users: jax.Array, n_rec: int = 10, *,
                       use_pallas: bool | None = None, bm: int = 512,
                       interpret: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """Full fused read path: scores + top-``n_rec`` unseen items.
    Returns ((B, n_rec) scores, (B, n_rec) item ids)."""
    scores = knn_scores(ratings, w, nbrs, users, use_pallas=use_pallas,
                        bm=bm, interpret=interpret)
    return jax.lax.top_k(scores, n_rec)
