"""Jit'd public wrapper for the k-way merge-insert kernel family.

Pre-conditions the inputs (per-row stable sort of the gated inserts,
NEG_INF gating of masked lanes, POS_INF column padding) and routes to one
of two equivalent backends:

  * ``use_pallas=True``  — the fused Pallas kernel (``kernel.py``;
    ``interpret=True`` executes it on CPU, pass False on a real TPU);
  * ``use_pallas=False`` — a pure-XLA merge: two ``searchsorted`` rank
    computations plus one scatter, O(R·(L + k)) data movement.

``use_pallas=None`` (default) picks the Pallas kernel on TPU backends and
the XLA merge elsewhere.  Both are asserted element-identical to the
``ref.py`` oracle (and hence to k sequential inserts) in the tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.list_merge.kernel import merge_insert_pallas
from repro.kernels.list_merge.ref import NEG_INF, POS_INF


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _sort_inserts(ins_vals: jax.Array, ins_idx: jax.Array,
                  ins_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gate masked lanes to NEG_INF and stable-sort each row's inserts
    ascending — ties keep burst order, masked lanes sort to the front."""
    gated = jnp.where(ins_mask, ins_vals, NEG_INF)
    order = jnp.argsort(gated, axis=1, stable=True)
    return (jnp.take_along_axis(gated, order, axis=1),
            jnp.take_along_axis(ins_idx, order, axis=1))


def _merge_xla(vals: jax.Array, idx: jax.Array, sv: jax.Array,
               si: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Rank-and-scatter merge (no Pallas): the true O(R·(L + k)) path.

    Merged rank of insert t: #{row <= s_t} (side="right": equal row
    entries are older) + t; of row entry j: j + #{inserts < row[j]}
    (side="left": equal inserts are younger).  Ranks form a permutation of
    0..L+k-1; entries with rank >= k survive at output slot rank - k, the
    rest scatter to slot L and are dropped.
    """
    R, L = vals.shape
    k = sv.shape[1]
    p = jax.vmap(lambda row, s: jnp.searchsorted(row, s, side="right"))(
        vals, sv).astype(jnp.int32)
    rank_ins = p + jnp.arange(k, dtype=jnp.int32)[None, :]
    c = jax.vmap(lambda s, row: jnp.searchsorted(s, row, side="left"))(
        sv, vals).astype(jnp.int32)
    rank_row = jnp.arange(L, dtype=jnp.int32)[None, :] + c

    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    t_row = jnp.where(rank_row >= k, rank_row - k, L)    # L -> dropped
    t_ins = jnp.where(rank_ins >= k, rank_ins - k, L)
    out_v = jnp.zeros_like(vals).at[rows, t_row].set(vals, mode="drop")
    out_i = jnp.zeros_like(idx).at[rows, t_row].set(idx, mode="drop")
    out_v = out_v.at[rows, t_ins].set(sv.astype(vals.dtype), mode="drop")
    out_i = out_i.at[rows, t_ins].set(si.astype(idx.dtype), mode="drop")
    return out_v, out_i


@partial(jax.jit, static_argnames=("use_pallas", "br", "interpret"))
def merge_insert(vals: jax.Array, idx: jax.Array, ins_vals: jax.Array,
                 ins_idx: jax.Array, ins_mask: jax.Array | None = None, *,
                 use_pallas: bool | None = None, br: int = 8,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Merge k (value, index) inserts into each of R ascending lists.

    Args:
      vals:     (R, L) float32 ascending per row, values in
                (NEG_INF, POS_INF).
      idx:      (R, L) int32 companion indices.
      ins_vals: (R, k) insert values in burst order (k-th axis).
      ins_idx:  (k,) or (R, k) int32 insert indices.
      ins_mask: optional (R, k) bool; False lanes are exact no-ops for
                that row.

    Returns (vals', idx') of shape (R, L): the merged lists with the k
    smallest merged elements dropped — element-identical to k sequential
    drop-min ``searchsorted(side="right")`` shift-inserts in burst order.
    """
    R, L = vals.shape
    k = ins_vals.shape[-1]
    vals = vals.astype(jnp.float32)
    idx = idx.astype(jnp.int32)
    ins_vals = jnp.broadcast_to(ins_vals.astype(jnp.float32), (R, k))
    ins_idx = jnp.broadcast_to(ins_idx.astype(jnp.int32), (R, k))
    if ins_mask is None:
        ins_mask = jnp.ones((R, k), jnp.bool_)
    else:
        ins_mask = jnp.broadcast_to(ins_mask, (R, k))

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        sv, si = _sort_inserts(ins_vals, ins_idx, ins_mask)
        return _merge_xla(vals, idx, sv, si)

    # Pallas path: pad insert lanes BEFORE the sort (NEG_INF lanes self-
    # drop and must not trail the ascending order, see ref.py), rows to
    # the block multiple, columns to LP >= L + kp on a lane boundary.
    # Padded rows/columns are sliced away below.
    kp = max(8, _round_up(k, 8))
    Rp = _round_up(R, br)
    LP = _round_up(L + kp, 128)
    ins_vals = jnp.pad(ins_vals, ((0, Rp - R), (0, kp - k)))
    ins_idx = jnp.pad(ins_idx, ((0, Rp - R), (0, kp - k)))
    ins_mask = jnp.pad(ins_mask, ((0, Rp - R), (0, kp - k)))
    sv, si = _sort_inserts(ins_vals, ins_idx, ins_mask)
    vp = jnp.pad(vals, ((0, Rp - R), (0, LP - L)),
                 constant_values=float(POS_INF))
    ip = jnp.pad(idx, ((0, Rp - R), (0, LP - L)))
    out_v, out_i = merge_insert_pallas(vp, ip, sv, si, br=br,
                                       interpret=interpret)
    return out_v[:R, :L], out_i[:R, :L]
