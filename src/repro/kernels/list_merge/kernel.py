"""Pallas TPU kernel: fused k-way merge-insert for ascending sorted lists.

One grid step owns a (br, LP) block of rows and merges each row's k
pre-sorted inserts in a single pass, replacing k sequential shift-gathers
(k full HBM round-trips of the (N, N) arena) with one read + one write:

  1. insert ranks:   rank_t = |{j : row[j] <= s_t}| + t — one broadcast
                     compare-reduce per insert (the k-way ``searchsorted``);
  2. merge path:     b(j) = |{t : rank_t < j + k}| counts inserts landing
                     strictly before output slot j (merged rank j + k, the
                     k smallest being dropped);
  3. gather:         out[j] = row[j + k − b(j)] or, when an insert's rank
                     equals j + k, ins[b(j)].  The data-dependent offset
                     k − b(j) ∈ [0, k] is resolved as k + 1 static shifted
                     selects, so the kernel needs no in-VMEM gather.

Work per row is O(L·k) compares/selects on the VPU, all on (br, LP)
blocks; the inputs stream HBM -> VMEM once, totalling O(N·(N + k)) for the
whole arena versus the sequential path's k·O(N²).

Inputs must be pre-conditioned by ``ops.py``: inserts sorted ascending per
row with masked/padded lanes at ``NEG_INF``, list columns padded to LP >=
L + k with ``POS_INF`` (see ``ref.py`` for the value contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.kernels.list_merge.ref import POS_INF


def _shift_left(x: jax.Array, d: int) -> jax.Array:
    """x[:, j + d] with wrap-around; callers only select j + d < LP."""
    if d == 0:
        return x
    return jnp.concatenate([x[:, d:], x[:, :d]], axis=1)


def _merge_kernel(vals_ref, idx_ref, iv_ref, ii_ref, ov_ref, oi_ref, *,
                  kp: int):
    v = vals_ref[...]                                # (br, LP), pad POS_INF
    ids = idx_ref[...]                               # (br, LP) int32
    sv = iv_ref[...]                                 # (br, kp) ascending
    si = ii_ref[...]                                 # (br, kp) int32
    br, LP = v.shape

    # 1. insert ranks: rank_t = #{row entries <= s_t} + t.  Row entries tie-
    # break before inserts (side="right"); among equal inserts the +t term
    # preserves burst order.  POS_INF column pads never count.
    ranks = []
    for t in range(kp):
        p = jnp.sum((v <= sv[:, t:t + 1]).astype(jnp.int32), axis=1,
                    keepdims=True)                   # (br, 1)
        ranks.append(p + t)

    # 2. merge path: output slot j holds merged rank j + kp (first kp
    # dropped); b(j) inserts precede it, and it IS insert b(j) iff some
    # rank_t == j + kp (ranks are strictly increasing in t).
    tgt = jax.lax.broadcasted_iota(jnp.int32, (br, LP), 1) + kp
    b = jnp.zeros((br, LP), jnp.int32)
    is_ins = jnp.zeros((br, LP), jnp.bool_)
    for t in range(kp):
        b += (ranks[t] < tgt).astype(jnp.int32)
        is_ins |= ranks[t] == tgt

    # 3. gather via static shifted selects: row part reads row[j + kp - b].
    out_v = jnp.zeros((br, LP), v.dtype)
    out_i = jnp.zeros((br, LP), ids.dtype)
    for d in range(kp + 1):
        sel = jnp.logical_not(is_ins) & (b == kp - d)
        out_v = jnp.where(sel, _shift_left(v, d), out_v)
        out_i = jnp.where(sel, _shift_left(ids, d), out_i)
    for t in range(kp):
        sel = is_ins & (b == t)
        out_v = jnp.where(sel, sv[:, t:t + 1], out_v)
        out_i = jnp.where(sel, si[:, t:t + 1], out_i)
    ov_ref[...] = out_v
    oi_ref[...] = out_i


def merge_insert_pallas(vals: jax.Array, idx: jax.Array,
                        ins_vals: jax.Array, ins_idx: jax.Array, *,
                        br: int = 8, interpret: bool = True
                        ) -> tuple[jax.Array, jax.Array]:
    """(R, LP) padded lists + (R, kp) sorted gated inserts -> merged (R, LP).

    ``ops.py`` handles padding (rows to br, columns to LP >= L + kp with
    POS_INF, insert lanes to kp with NEG_INF) and slices the result back.
    Only the leading L output columns are meaningful.
    """
    R, LP = vals.shape
    R2, kp = ins_vals.shape
    assert R == R2 and R % br == 0, (vals.shape, ins_vals.shape, br)
    assert idx.shape == (R, LP) and ins_idx.shape == (R, kp)
    grid = (R // br,)
    kernel = functools.partial(_merge_kernel, kp=kp)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LP), lambda i: (i, 0)),
            pl.BlockSpec((br, LP), lambda i: (i, 0)),
            pl.BlockSpec((br, kp), lambda i: (i, 0)),
            pl.BlockSpec((br, kp), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((br, LP), lambda i: (i, 0)),
            pl.BlockSpec((br, LP), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((R, LP), vals.dtype),
            jax.ShapeDtypeStruct((R, LP), jnp.int32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(vals, idx, ins_vals, ins_idx)
