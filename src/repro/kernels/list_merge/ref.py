"""Pure-jnp oracle for the k-way merge-insert kernel.

Semantics (shared by every backend): each row holds an ascending list of
width L; a burst of k (value, index) inserts is merged in *burst order*
and the k smallest elements of the merged (L + k) multiset are dropped.
This reproduces exactly k sequential drop-min shift-inserts with
``searchsorted(side="right")`` placement: on equal values the incumbent
(older) entry is the one dropped at the head and the newer one lands to
its right, so the merged order is (value ascending, age ascending) with
row entries older than every insert and inserts aged by burst position.

Masked-off inserts take the value ``NEG_INF`` (strictly below the
SENTINEL): they sort to the very front of the merged order and are always
among the k dropped, i.e. they are exact no-ops.  Per-row gating and
lane padding therefore share one mechanism.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Strictly below SENTINEL (-2.0): a masked or padded insert sorts ahead of
# every live or sentinel list entry and is always dropped.
NEG_INF = jnp.float32(-3.0)
# Strictly above any real similarity / list value: column padding for the
# Pallas path.  List values must lie in (NEG_INF, POS_INF).
POS_INF = jnp.float32(4.0)


def merge_insert_ref(vals: jax.Array, idx: jax.Array, ins_vals: jax.Array,
                     ins_idx: jax.Array, ins_mask: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """(R, L) ascending lists + (R, k) burst-order inserts -> merged lists.

    A stable argsort over the concatenated (R, L + k) block orders ties as
    (value, age) — row entries first, then inserts in burst order, which is
    exactly the order k sequential ``side="right"`` inserts produce — and
    the first k positions are the dropped minima.
    """
    k = ins_vals.shape[1]
    gated = jnp.where(ins_mask, ins_vals.astype(vals.dtype), NEG_INF)
    mvals = jnp.concatenate([vals, gated], axis=1)
    midx = jnp.concatenate([idx, ins_idx.astype(idx.dtype)], axis=1)
    order = jnp.argsort(mvals, axis=1, stable=True)[:, k:]
    return (jnp.take_along_axis(mvals, order, axis=1),
            jnp.take_along_axis(midx, order, axis=1))
