"""Jit'd wrapper for the verification kernel (pad + run + squeeze)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.verify_rows.kernel import verify_rows_pallas


@partial(jax.jit, static_argnames=("bs", "bk", "interpret"))
def verify_rows(C: jax.Array, r0: jax.Array, valid: jax.Array, *,
                bs: int = 256, bk: int = 512,
                interpret: bool = True) -> jax.Array:
    """(s, m) candidates vs (m,) target -> (s,) bool verified-twin flags."""
    s, m = C.shape
    ps, pk = (-s) % bs, (-m) % bk
    Cp = jnp.pad(C, ((0, ps), (0, pk)))
    # Padded item columns must match on padded rows too: r0 pads with zeros,
    # matching C's zero padding, so equality is preserved.
    r0p = jnp.pad(r0, (0, pk))
    vp = jnp.pad(valid, (0, ps))            # padded rows -> invalid
    out = verify_rows_pallas(Cp, r0p, vp, bs=bs, bk=bk, interpret=interpret)
    return out[:s, 0]
