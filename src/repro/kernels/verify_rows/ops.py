"""Jit'd wrapper for the verification kernel (pad + run + squeeze)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.verify_rows.kernel import verify_rows_pallas


@jax.jit
def rows_sorted_finite(vals: jax.Array, n_active: jax.Array) -> jax.Array:
    """(R, L) per-row invariant flags: live rows must be finite and
    ascending.  The serving layer's cheap poison detector — one fused
    reduction over the arena, same row-major streaming access pattern as
    the verification kernel (verify_rows checks candidate rows against a
    target; this checks every row against its own ordering contract)."""
    R = vals.shape[0]
    live = jnp.arange(R, dtype=jnp.int32) < n_active
    finite = jnp.all(jnp.isfinite(vals), axis=1)
    ascending = jnp.all(jnp.diff(vals, axis=1) >= 0, axis=1)
    return (finite & ascending) | ~live


@jax.jit
def arena_healthy(sim_vals: jax.Array, ratings: jax.Array,
                  norms: jax.Array, n_active: jax.Array) -> jax.Array:
    """() bool — the whole-arena NaN/ordering invariant the snapshot and
    rollback machinery keys on: live similarity lists sorted ascending with
    no non-finite values, live rating rows and norms finite, ``n_active``
    within capacity."""
    R = ratings.shape[0]
    live = jnp.arange(R, dtype=jnp.int32) < n_active
    lists_ok = jnp.all(rows_sorted_finite(sim_vals, n_active))
    ratings_ok = jnp.all(jnp.all(jnp.isfinite(ratings), axis=1) | ~live)
    norms_ok = jnp.all((jnp.isfinite(norms) & (norms >= 0)) | ~live)
    n_ok = (n_active >= 0) & (n_active <= R)
    return lists_ok & ratings_ok & norms_ok & n_ok


@partial(jax.jit, static_argnames=("bs", "bk", "interpret"))
def verify_rows(C: jax.Array, r0: jax.Array, valid: jax.Array, *,
                bs: int = 256, bk: int = 512,
                interpret: bool = True) -> jax.Array:
    """(s, m) candidates vs (m,) target -> (s,) bool verified-twin flags."""
    s, m = C.shape
    ps, pk = (-s) % bs, (-m) % bk
    Cp = jnp.pad(C, ((0, ps), (0, pk)))
    # Padded item columns must match on padded rows too: r0 pads with zeros,
    # matching C's zero padding, so equality is preserved.
    r0p = jnp.pad(r0, (0, pk))
    vp = jnp.pad(valid, (0, ps))            # padded rows -> invalid
    out = verify_rows_pallas(Cp, r0p, vp, bs=bs, bk=bk, interpret=interpret)
    return out[:s, 0]
