"""Pallas TPU kernel: fused masked row-equality verification.

Algorithm 1 lines 10-15: test each gathered candidate row against the new
user's rating vector.  The kernel streams (bs, bk) blocks of the candidate
matrix through VMEM, AND-reduces equality per row across the item grid axis
in an int32 scratch accumulator (TPU-friendly lane layout), and applies the
candidate-validity mask in the epilogue.  Bandwidth-bound by design — the
paper's O(|Set_0|·m) term — so the win over the jnp oracle on real hardware
is the fusion (one pass, no (s, m) bool intermediate in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _verify_kernel(c_ref, r0_ref, valid_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.ones_like(acc_ref)

    eq_blk = (c_ref[...] == r0_ref[...][None, :]).all(axis=1)
    acc_ref[...] &= eq_blk[:, None]

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...] & (valid_ref[...][:, None])


def verify_rows_pallas(C: jax.Array, r0: jax.Array, valid: jax.Array, *,
                       bs: int = 256, bk: int = 512,
                       interpret: bool = True) -> jax.Array:
    """C: (s, m) candidate rows; r0: (m,); valid: (s,) bool.
    Returns (s, 1) bool — row i equals r0 and is a live candidate."""
    s, m = C.shape
    assert s % bs == 0 and m % bk == 0, (C.shape, (bs, bk))
    nk = m // bk
    kernel = functools.partial(_verify_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(s // bs, nk),
        in_specs=[
            pl.BlockSpec((bs, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk,), lambda i, k: (k,)),
            pl.BlockSpec((bs,), lambda i, k: (i,)),
        ],
        out_specs=pl.BlockSpec((bs, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, 1), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((bs, 1), jnp.bool_)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(C, r0, valid)
