"""Pure-jnp oracle for the verification kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def verify_rows_ref(C: jax.Array, r0: jax.Array,
                    valid: jax.Array) -> jax.Array:
    eq = jnp.all(C == r0[None, :], axis=1)
    return (eq & valid)[:, None]


def rows_sorted_finite_ref(vals: jax.Array, live: jax.Array) -> jax.Array:
    """Numpy-style oracle for the arena invariant: every live row is finite
    and ascending (sentinel head included — sentinels are the minimum)."""
    finite = jnp.all(jnp.isfinite(vals) | ~live[:, None], axis=1)
    ascending = jnp.all((jnp.diff(vals, axis=1) >= 0) | ~live[:, None],
                        axis=1)
    return finite & ascending
