"""Pure-jnp oracle for the verification kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def verify_rows_ref(C: jax.Array, r0: jax.Array,
                    valid: jax.Array) -> jax.Array:
    eq = jnp.all(C == r0[None, :], axis=1)
    return (eq & valid)[:, None]
