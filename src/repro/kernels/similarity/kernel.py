"""Pallas TPU kernel: blocked cosine-similarity matmul with fused
normalisation epilogue.

This is the paper's measured hot spot: the traditional new-user path
computes sim(u0, x) for all n users over m items — a (nq, m) x (m, n)
matmul — and the full build is the (n, m) x (m, n) case.  The kernel tiles
(bq, bn, bk) blocks into VMEM, accumulates fp32 partial dot products on the
MXU over the item (k) grid axis, and divides by the cached row norms in the
epilogue of the final k step — the normalisation never touches HBM as a
separate pass.

Block shapes default to MXU-aligned multiples of 128; the (bq, bk) + (bn,
bk) + (bq, bn) working set at the defaults is ~0.8 MB, comfortably inside
the ~16 MB VMEM budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

EPS = 1e-12


def _sim_kernel(qn_ref, rn_ref, q_ref, r_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        q_ref[...], r_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        denom = jnp.maximum(
            qn_ref[...][:, None] * rn_ref[...][None, :], EPS)
        o_ref[...] = acc_ref[...] / denom


def similarity_pallas(Q: jax.Array, R: jax.Array, q_norms: jax.Array,
                      r_norms: jax.Array, *, bq: int = 128, bn: int = 256,
                      bk: int = 512, interpret: bool = True) -> jax.Array:
    """(nq, m), (n, m) -> (nq, n) cosine similarity, fp32.

    Dimensions must be pre-padded to the block multiples (``ops.py`` does
    this); zero-padded rows produce sim 0 via the EPS-guarded denominator.
    """
    nq, m = Q.shape
    n, m2 = R.shape
    assert m == m2 and nq % bq == 0 and n % bn == 0 and m % bk == 0, (
        Q.shape, R.shape, (bq, bn, bk))
    nk = m // bk
    grid = (nq // bq, n // bn, nk)

    kernel = functools.partial(_sim_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_norms, r_norms, Q, R)
