"""Jit'd public wrapper: pad to block multiples, run the kernel, slice.

``interpret=True`` executes the kernel body on CPU (this container);
on a real TPU pass ``interpret=False``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.similarity.kernel import similarity_pallas
from repro.kernels.similarity.ref import EPS


def _pad(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bq", "bn", "bk", "interpret"))
def cosine_similarity(Q: jax.Array, R: jax.Array,
                      q_norms: jax.Array | None = None,
                      r_norms: jax.Array | None = None, *,
                      bq: int = 128, bn: int = 256, bk: int = 512,
                      interpret: bool = True) -> jax.Array:
    """Cosine similarity of each row of Q against each row of R — the
    traditional-path hot loop, on the Pallas kernel."""
    if q_norms is None:
        q_norms = jnp.linalg.norm(Q.astype(jnp.float32), axis=1)
    if r_norms is None:
        r_norms = jnp.linalg.norm(R.astype(jnp.float32), axis=1)
    nq, n = Q.shape[0], R.shape[0]
    Qp = _pad(_pad(Q, bq, 0), bk, 1)
    Rp = _pad(_pad(R, bn, 0), bk, 1)
    qn = jnp.maximum(_pad(q_norms.astype(jnp.float32), bq, 0), EPS)
    rn = jnp.maximum(_pad(r_norms.astype(jnp.float32), bn, 0), EPS)
    out = similarity_pallas(Qp, Rp, qn, rn, bq=bq, bn=bn, bk=bk,
                            interpret=interpret)
    return out[:nq, :n]
