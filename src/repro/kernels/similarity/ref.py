"""Pure-jnp oracle for the similarity kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def similarity_ref(Q: jax.Array, R: jax.Array, q_norms: jax.Array,
                   r_norms: jax.Array) -> jax.Array:
    dots = jnp.einsum("qm,nm->qn", Q.astype(jnp.float32),
                      R.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    denom = jnp.maximum(q_norms[:, None] * r_norms[None, :], EPS)
    return dots / denom
