"""Version shims for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` upstream;
this container pins a jax where only the old name exists.  Every kernel
imports the alias from here so the family works on either side of the
rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
