"""Jit'd wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas


@partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jax.Array, idx: jax.Array,
                  weights: jax.Array | None = None,
                  mask: jax.Array | None = None, *,
                  interpret: bool = True) -> jax.Array:
    """Sum-combiner EmbeddingBag: (V, dim) table, (n_bags, hot) indices,
    optional per-sample weights and validity mask -> (n_bags, dim)."""
    n_bags, hot = idx.shape
    if weights is None:
        weights = jnp.ones((n_bags, hot), jnp.float32)
    if mask is not None:
        weights = weights * mask.astype(weights.dtype)
    idx = jnp.clip(idx.astype(jnp.int32), 0, table.shape[0] - 1)
    return embedding_bag_pallas(table, idx, weights.astype(jnp.float32),
                                interpret=interpret)
