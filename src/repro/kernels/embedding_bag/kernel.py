"""Pallas TPU kernel: EmbeddingBag via scalar-prefetch-driven row gather.

The recsys substrate's hot path: sum (or weighted-sum) of ``hot`` embedding
rows per bag from a large table.  The classic TPU pattern: bag indices ride
in scalar memory (``PrefetchScalarGridSpec``) and *drive the BlockSpec
index_map*, so each grid step DMAs exactly the (1, dim) table row it needs
from HBM — the gather never materialises an (n_bags·hot, dim) intermediate.
Accumulation happens in the revisited output block across the ``hot`` grid
axis (h == 0 initialises).

Weights fold in the multi-hot validity mask (0.0 = padding slot), matching
``torch.nn.EmbeddingBag(mode='sum', per_sample_weights=...)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _bag_kernel(idx_ref, w_ref, table_ref, o_ref):
    b = pl.program_id(0)
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[b, h]
    o_ref[...] += table_ref[...] * w


def embedding_bag_pallas(table: jax.Array, idx: jax.Array,
                         weights: jax.Array, *,
                         interpret: bool = True) -> jax.Array:
    """table: (V, dim); idx: (n_bags, hot) int32; weights: (n_bags, hot)
    f32 (0 for padding slots).  Returns (n_bags, dim) weighted bag sums."""
    n_bags, hot = idx.shape
    V, dim = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_bags, hot),
        in_specs=[
            pl.BlockSpec((1, dim), lambda b, h, idx_ref, w_ref:
                         (idx_ref[b, h], 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda b, h, idx_ref, w_ref:
                               (b, 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, dim), table.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(idx, weights, table)
