"""Pure-jnp oracle for the embedding-bag kernel (gather + masked sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, idx: jax.Array,
                      weights: jax.Array) -> jax.Array:
    rows = jnp.take(table, idx, axis=0)                  # (n_bags, hot, dim)
    return jnp.sum(rows * weights[..., None].astype(rows.dtype), axis=1)
