"""Edge-parallel full-graph GAT under ``shard_map``.

Baseline (§Perf Cell B): with edges sharded over all axes and node tensors
replicated, GSPMD resolves the segment-scatter by all-gathering the
(E, H, F') message tensor — 16.5GB/device on ogbn-products, 20GB temp,
useful fraction 0.01.  The explicit formulation keeps messages local to
their edge shard and combines node aggregates with psums:

  per shard:  e_loc = LeakyReLU(a_src·Wh[src_loc] + a_dst·Wh[dst_loc])
              m     = pmax(segment_max(e_loc))            (N, H)
              Z     = psum(segment_sum(exp(e_loc − m)))   (N, H)
              out   = psum(segment_sum(alpha · Wh[src_loc]))  (N, H, F')

Node projections are computed replicated (N·d·H·F' flops ≈ 31 GFLOP on
products — negligible against the removed 16.5GB of traffic); per-layer
collective traffic drops to ~780MB of (N, H(·F')) psums.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import shard_map

from repro.configs.base import GNNConfig


class GNNEPInfo(NamedTuple):
    axes: tuple[str, ...]        # all mesh axes (edge sharding + psums)
    mesh: object = None


def _pmax_sg(x, axes):
    """pmax with stop-gradient semantics (pmax lacks a JVP rule; the max
    only stabilises the softmax, so a zero tangent is exact)."""
    @jax.custom_jvp
    def f(v):
        return jax.lax.pmax(v, axes)

    @f.defjvp
    def _jvp(primals, tangents):
        out = f(primals[0])
        return out, jnp.zeros_like(out)

    return f(x)


def _gat_layer_local(x, src, dst, lp, n_heads, negative_slope, concat,
                     axes):
    N = x.shape[0]
    Wh = jnp.einsum("nf,fo->no", x, lp["W"].astype(x.dtype))
    Wh = Wh.reshape(N, n_heads, -1)
    e_src = jnp.einsum("nhf,hf->nh", Wh, lp["a_src"].astype(x.dtype))
    e_dst = jnp.einsum("nhf,hf->nh", Wh, lp["a_dst"].astype(x.dtype))
    e = jax.nn.leaky_relu(e_src[src] + e_dst[dst], negative_slope)
    e = e.astype(jnp.float32)

    m_loc = jax.ops.segment_max(e, dst, num_segments=N)
    m = _pmax_sg(jnp.where(jnp.isfinite(m_loc), m_loc, -1e30), axes)
    m = jnp.where(m > -1e29, jax.lax.stop_gradient(m), 0.0)
    ex = jnp.exp(e - m[dst])
    denom = jax.lax.psum(jax.ops.segment_sum(ex, dst, num_segments=N),
                         axes)
    alpha = (ex / jnp.maximum(denom[dst], 1e-16)).astype(x.dtype)
    msgs = Wh[src] * alpha[..., None]
    out = jax.lax.psum(
        jax.ops.segment_sum(msgs.astype(jnp.float32), dst,
                            num_segments=N), axes).astype(x.dtype)
    if concat:
        return out.reshape(N, -1)
    return jnp.mean(out, axis=1)


def forward_segment_ep(params: dict, feats: jax.Array, edge_src: jax.Array,
                       edge_dst: jax.Array, cfg: GNNConfig,
                       info: GNNEPInfo) -> jax.Array:
    """(N, d) replicated feats + edge lists sharded over every axis ->
    (N, n_classes) replicated logits."""

    def local(feats, src, dst, p):
        # remat each layer: the replicated (N, H·F') node tensors dominate
        # per-device memory; recomputing them in the backward halves the
        # simultaneous-liveness set (§Perf Cell B iteration 2).
        layer = jax.checkpoint(
            lambda x, lp, concat: _gat_layer_local(
                x, src, dst, lp, cfg.n_heads, cfg.negative_slope, concat,
                info.axes), static_argnums=(2,),
            policy=jax.checkpoint_policies.nothing_saveable)
        h = jax.nn.elu(layer(feats, p["l1"], True))
        return layer(h, p["l2"], False)

    return shard_map(
        local,
        mesh=info.mesh,
        in_specs=(P(None, None), P(info.axes), P(info.axes),
                  jax.tree.map(lambda _: P(None, None), params)),
        out_specs=P(None, None),
        check_vma=False,
    )(feats, edge_src, edge_dst, params)


def loss_full_ep(params, batch, cfg: GNNConfig, info: GNNEPInfo):
    from repro.models.gnn import node_xent
    logits = forward_segment_ep(params, batch["feats"], batch["edge_src"],
                                batch["edge_dst"], cfg, info)
    return node_xent(logits, batch["labels"], batch["mask"])
