"""Mixture-of-Experts FFN with grouped scatter dispatch.

Tokens are split into groups (sharded over the data axes); within each group
every token's top-k expert choices get a slot in a per-(group, expert)
capacity buffer via a cumsum rank, and dispatch/combine are gather/scatter —
O(T·k·d) data movement — rather than GShard's one-hot dispatch einsum, which
costs O(T·E·C·d) FLOPs and is a non-starter at E=64.  The (G, E, C, d)
buffer shards (G → data axes, E → model axis), so the dp↔model traffic GSPMD
inserts around the scatter/gather *is* the classic MoE all-to-all pair.

Expert compute is a single batched einsum over the (E-sharded) expert stack.
Aux load-balance loss follows Switch (E · Σ_e f_e · p_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import ffn


def _capacity(group_size: int, cfg: MoEConfig) -> int:
    c = int(group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)        # round up to 8


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_ffn(x: jax.Array, router_w: jax.Array, w_in: jax.Array,
            w_out: jax.Array, shared: tuple[jax.Array, jax.Array] | None,
            cfg: MoEConfig, act: str, *, group_size: int = 4096,
            tokens_spec=None, experts_spec=None
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    router_w: (d, E); w_in: (E, d, F·glu); w_out: (E, F, d);
    shared: optional (w_in_sh, w_out_sh) always-on expert.

    ``tokens_spec`` (P(dp, None, None)) pins token groups and the dispatch
    buffer to the data axes so the capacity scatter is shard-local — without
    it GSPMD replicates the (G, E, C, d) buffer over the model axis and
    all-reduces it (measured: ~60x the intrinsic all-to-all traffic).
    ``experts_spec`` (P(dp, mp, None, None)) shards the expert outputs on E
    so the combine gather is the only cross-axis exchange (the MoE
    all-to-all analogue under GSPMD).
    """
    B, S, d = x.shape
    E, k, = cfg.n_experts, cfg.top_k
    T = B * S
    gs = min(group_size, T)
    G = T // gs
    assert G * gs == T, (T, gs)
    C = _capacity(gs, cfg)

    xt = _constrain(x.reshape(G, gs, d), tokens_spec)
    logits = jnp.einsum("gtd,de->gte", xt, router_w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (G, gs, E) fp32
    gates, eidx = jax.lax.top_k(probs, k)                # (G, gs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # Slot assignment: rank of each (token, choice) within its expert, in
    # token-major order (GShard priority), via a cumsum over the group.
    onehot = jax.nn.one_hot(eidx.reshape(G, gs * k), E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=1) - 1               # (G, gs·k, E)
    pos = jnp.sum(ranks * onehot, axis=-1)               # (G, gs·k)
    eflat = eidx.reshape(G, gs * k)
    valid = pos < C
    # Dropped (over-capacity) choices clamp to the last slot with a zeroed
    # contribution — no ragged +1 bin, so every buffer dim stays divisible
    # by the expert (model-axis) sharding.
    slot = jnp.where(valid, eflat * C + jnp.minimum(pos, C - 1), E * C - 1)

    # Dispatch: scatter token activations into (G, E·C, d).
    gi = jnp.arange(G, dtype=jnp.int32)[:, None]
    xk = jnp.repeat(xt, k, axis=1) * valid[..., None].astype(x.dtype)
    buf = jnp.zeros((G, E * C, d), x.dtype).at[gi, slot].add(xk)
    buf = _constrain(buf, tokens_spec)                   # shard-local scatter
    buf = buf.reshape(G, E, C, d)

    # Expert compute (E shards over the model axis).
    if act in ("swiglu", "geglu"):
        gu = jnp.einsum("gecd,edf->gecf", buf, w_in.astype(x.dtype))
        gate_h, up = jnp.split(gu, 2, axis=-1)
        inner = {"swiglu": jax.nn.silu,
                 "geglu": lambda v: jax.nn.gelu(v, approximate=True)}[act](
                     gate_h) * up
    else:
        inner = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf,
                                       w_in.astype(x.dtype)))
    inner = _constrain(inner, experts_spec)
    out_buf = jnp.einsum("gecf,efd->gecd", inner, w_out.astype(x.dtype))
    out_buf = _constrain(out_buf, experts_spec)
    out_buf = out_buf.reshape(G, E * C, d)

    # Combine: gather each choice's output, weight by its gate (dropped
    # choices carry weight 0, so the clamped slot's garbage never lands).
    yk = _constrain(out_buf[gi, slot], tokens_spec)      # (G, gs·k, d)
    w = (gates.reshape(G, gs * k) * valid).astype(x.dtype)
    y = jnp.sum(yk.reshape(G, gs, k, d) * w.reshape(G, gs, k, 1), axis=2)
    y = y.reshape(B, S, d)

    if shared is not None:
        y = y + ffn(x, shared[0], shared[1], act)

    # Switch load-balance aux: E · mean_e(f_e · p_e).
    frac = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32),
                            axis=2), axis=(0, 1))        # (E,) token fracs /k
    prob = jnp.mean(probs, axis=(0, 1))                  # (E,)
    aux = E * jnp.sum(frac / k * prob)
    return y, aux
