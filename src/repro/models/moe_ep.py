"""Expert-parallel MoE under ``shard_map`` — explicit all-to-all dispatch.

The GSPMD-constraint formulation in ``moe.py`` is portable (and the §Perf
baseline), but the partitioner materialises replicated activation-sized
gradients around the dispatch scatter (measured ~95GB/device of all-reduce
per layer on llama4-scout).  This module is the production path: the token
<-> expert exchange is written as the textbook pair of ``all_to_all``s over
the model axis, with FSDP weight shards explicitly ``all_gather``ed (and
reduce-scattered in the backward, via the all_gather transpose):

  tokens (sharded dp x mp)  --a2a-->  expert rows (E/mp experts per shard)
        expert GEMMs (full f, weights gathered over dp)
  expert rows  --a2a-->  tokens, combine with gates

Per-device traffic: 2 x T_loc·k·cf·d activation bytes over the model axis +
one weight gather over dp per layer — the intrinsic MoE cost.

Semantics match ``moe.py`` exactly when nothing overflows capacity (same
per-token expert dot products); capacity accounting is per *local* shard,
which is the standard EP formulation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import shard_map

from repro.configs.base import MoEConfig


class MoEEPInfo(NamedTuple):
    """Static routing info the sharding layer hands the model."""

    dp: tuple[str, ...]          # data axes (token sharding / weight FSDP)
    mp: str                      # model axis (expert sharding / all-to-all)
    mp_size: int
    win_spec: object             # P of the sliced (E, d, gf·f) w_in
    wout_spec: object            # P of the sliced (E, f, d) w_out
    acts_spec: object            # P of the (B, S, d) activations
    mesh: object = None          # concrete Mesh (bound at cell build)


def _gather_axes(spec) -> tuple:
    """dp axes on the last dim of a weight spec (() = not FSDP-sharded)."""
    last = tuple(spec)[-1] if len(tuple(spec)) else None
    if last is None:
        return ()
    return last if isinstance(last, tuple) else (last,)


def moe_ffn_ep(x: jax.Array, router_w: jax.Array, w_in: jax.Array,
               w_out: jax.Array, cfg: MoEConfig, act: str,
               info: MoEEPInfo) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) sharded ``info.acts_spec`` -> (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    mp_n = info.mp_size
    assert E % mp_n == 0, (E, mp_n)
    E_loc = E // mp_n
    glu = act in ("swiglu", "geglu")
    win_gather = _gather_axes(info.win_spec)
    wout_gather = _gather_axes(info.wout_spec)

    def local_fn(x_loc, rw, w_in_loc, w_out_loc):
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        xt = x_loc.reshape(T, d)
        logits = jnp.einsum("td,de->te", xt, rw.astype(xt.dtype),
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)            # (T, k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

        C = max(8, -(-int(T * k * cfg.capacity_factor / E) // 8) * 8)
        flat_e = eidx.reshape(-1)                        # (T·k,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)
        valid = pos < C
        slot = flat_e * C + jnp.minimum(pos, C - 1)      # [0, E·C)

        xk = jnp.repeat(xt, k, axis=0) * valid[:, None].astype(xt.dtype)
        send = jnp.zeros((E * C, d), xt.dtype).at[slot].add(xk)

        # ---- dispatch all-to-all over the model axis ----
        recv = jax.lax.all_to_all(send, info.mp, split_axis=0,
                                  concat_axis=0, tiled=True)
        # (mp·E_loc·C, d): peer-major blocks of my local experts' rows.
        recv = recv.reshape(mp_n, E_loc, C, d).transpose(1, 0, 2, 3)
        recv = recv.reshape(E_loc, mp_n * C, d)

        # ---- expert GEMMs (FSDP weight shards gathered over dp) ----
        w_in_full = (jax.lax.all_gather(w_in_loc, win_gather, axis=2,
                                        tiled=True)
                     if win_gather else w_in_loc)        # (E_loc, d, gf·f)
        w_out_full = (jax.lax.all_gather(w_out_loc, wout_gather, axis=2,
                                         tiled=True)
                      if wout_gather else w_out_loc)     # (E_loc, f, d)
        h = jnp.einsum("ecd,edf->ecf", recv, w_in_full.astype(recv.dtype))
        if glu:
            g, u = jnp.split(h, 2, axis=-1)
            inner = {"swiglu": jax.nn.silu,
                     "geglu": lambda v: jax.nn.gelu(v, approximate=True)}[
                         act](g) * u
        else:
            inner = jax.nn.gelu(h)
        out = jnp.einsum("ecf,efd->ecd", inner,
                         w_out_full.astype(inner.dtype))

        # ---- combine all-to-all (reverse of dispatch) ----
        back = out.reshape(E_loc, mp_n, C, d).transpose(1, 0, 2, 3)
        back = back.reshape(E * C, d)
        ret = jax.lax.all_to_all(back, info.mp, split_axis=0,
                                 concat_axis=0, tiled=True)
        yk = ret[slot] * (gates.reshape(-1) *
                          valid.astype(jnp.float32)).astype(
            ret.dtype)[:, None]
        y = jnp.sum(yk.reshape(T, k, d), axis=1).reshape(Bl, Sl, d)

        # ---- global load-balance aux (Switch) ----
        all_axes = info.dp + (info.mp,)
        frac = jax.lax.psum(jnp.sum(onehot.astype(jnp.float32), axis=0),
                            all_axes)
        prob = jax.lax.psum(jnp.sum(probs, axis=0), all_axes)
        t_tot = jax.lax.psum(jnp.float32(T), all_axes)
        aux = E * jnp.sum((frac / (k * t_tot)) * (prob / t_tot))
        return y, aux

    y, aux = shard_map(
        local_fn,
        mesh=info.mesh,
        in_specs=(info.acts_spec, P(None, None), info.win_spec,
                  info.wout_spec),
        out_specs=(info.acts_spec, P()),
        check_vma=False,
    )(x, router_w, w_in, w_out)
    return y, aux
