"""Shared neural-net building blocks (pure JAX, params as pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None
          ) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def act_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "leaky_relu": jax.nn.leaky_relu,
    }[name]


def glu_ffn(x: jax.Array, w_in: jax.Array, w_out: jax.Array,
            act: str) -> jax.Array:
    """Gated FFN: w_in packs [gate | up] along its last axis."""
    gu = jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype))
    gate, up = jnp.split(gu, 2, axis=-1)
    inner = {"swiglu": jax.nn.silu, "geglu":
             lambda v: jax.nn.gelu(v, approximate=True)}[act](gate) * up
    return jnp.einsum("...f,fd->...d", inner, w_out.astype(x.dtype))


def dense_ffn(x: jax.Array, w_in: jax.Array, w_out: jax.Array,
              act: str = "gelu") -> jax.Array:
    h = act_fn(act)(jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype)))
    return jnp.einsum("...f,fd->...d", h, w_out.astype(x.dtype))


def ffn(x: jax.Array, w_in: jax.Array, w_out: jax.Array, act: str
        ) -> jax.Array:
    if act in ("swiglu", "geglu"):
        return glu_ffn(x, w_in, w_out, act)
    return dense_ffn(x, w_in, w_out, act)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(key: jax.Array, shape: tuple[int, ...], scale: float,
                dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def fan_in_init(key: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return normal_init(key, shape, fan_in ** -0.5, dtype)
