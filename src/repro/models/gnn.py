"""GAT message passing (arXiv:1710.10903) in JAX segment ops.

JAX has no CSR SpMM; message passing is built from first principles
(DESIGN.md §2): SDDMM-style edge scores -> segment-softmax over destination
nodes (``segment_max``/``segment_sum``) -> weighted scatter aggregation.
Three execution regimes, matching the assigned shapes:

  * full-graph (Cora / ogbn-products): flat edge lists, segment ops over all
    nodes; edges shard over the data axes, node tensors are psum-combined.
  * sampled minibatch (Reddit-scale): GraphSAGE-style fanout arrays; GAT
    attention runs densely over the (node, fanout) axis — no segment ops on
    the 114M-edge graph, only gathers from the sharded feature store.
  * batched small graphs (molecule): graphs flattened block-diagonally with
    a graph-id readout.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig, ShapeSpec
from repro.models.layers import fan_in_init, normal_init


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: GNNConfig, d_feat: int,
                n_out: int | None = None) -> dict:
    """2-layer GAT: d_feat -> (H x d_hidden, concat, ELU) -> n_classes."""
    dt = jnp.dtype(cfg.dtype)
    H, F = cfg.n_heads, cfg.d_hidden
    n_out = n_out or cfg.n_classes
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "l1": {
            "W": fan_in_init(k1, (d_feat, H * F), dt),
            "a_src": normal_init(k2, (H, F), F ** -0.5, dt),
            "a_dst": normal_init(k3, (H, F), F ** -0.5, dt),
        },
        "l2": {
            "W": fan_in_init(k4, (H * F, H * n_out), dt),
            "a_src": normal_init(k5, (H, n_out), n_out ** -0.5, dt),
            "a_dst": normal_init(k6, (H, n_out), n_out ** -0.5, dt),
        },
    }


# ---------------------------------------------------------------------------
# Segment-op GAT layer (full-graph / block-diagonal regimes)
# ---------------------------------------------------------------------------

def gat_layer_segment(x: jax.Array, edge_src: jax.Array, edge_dst: jax.Array,
                      lp: dict, n_heads: int, *, negative_slope: float = 0.2,
                      concat: bool = True) -> jax.Array:
    """x: (N, F_in); edges j->i as (src=j, dst=i).  Self-loops are the
    caller's responsibility (the data pipeline adds them)."""
    N = x.shape[0]
    Wh = jnp.einsum("nf,fo->no", x, lp["W"].astype(x.dtype))
    Wh = Wh.reshape(N, n_heads, -1)                      # (N, H, F')
    e_src = jnp.einsum("nhf,hf->nh", Wh, lp["a_src"].astype(x.dtype))
    e_dst = jnp.einsum("nhf,hf->nh", Wh, lp["a_dst"].astype(x.dtype))
    e = jax.nn.leaky_relu(e_src[edge_src] + e_dst[edge_dst],
                          negative_slope)                # (E, H)
    e = e.astype(jnp.float32)
    m = jax.ops.segment_max(e, edge_dst, num_segments=N)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.exp(e - m[edge_dst])
    denom = jax.ops.segment_sum(ex, edge_dst, num_segments=N)
    alpha = (ex / jnp.maximum(denom[edge_dst], 1e-16)).astype(x.dtype)
    msgs = Wh[edge_src] * alpha[..., None]               # (E, H, F')
    out = jax.ops.segment_sum(msgs, edge_dst, num_segments=N)
    if concat:
        return out.reshape(N, -1)
    return jnp.mean(out, axis=1)


def forward_segment(params: dict, feats: jax.Array, edge_src: jax.Array,
                    edge_dst: jax.Array, cfg: GNNConfig) -> jax.Array:
    """(N, d_feat) -> (N, n_classes) logits via 2 GAT layers."""
    h = gat_layer_segment(feats, edge_src, edge_dst, params["l1"],
                          cfg.n_heads, negative_slope=cfg.negative_slope)
    h = jax.nn.elu(h)
    return gat_layer_segment(h, edge_src, edge_dst, params["l2"],
                             cfg.n_heads, negative_slope=cfg.negative_slope,
                             concat=False)


# ---------------------------------------------------------------------------
# Dense-fanout GAT layer (sampled-minibatch regime)
# ---------------------------------------------------------------------------

def gat_layer_fanout(x_self: jax.Array, x_nbrs: jax.Array, lp: dict,
                     n_heads: int, *, negative_slope: float = 0.2,
                     concat: bool = True) -> jax.Array:
    """Attention over a fixed sampled neighbourhood (+ self-loop).

    x_self: (B, F_in); x_nbrs: (B, K, F_in)."""
    B, K, _ = x_nbrs.shape
    xs = jnp.concatenate([x_self[:, None], x_nbrs], axis=1)  # (B, 1+K, F)
    Wh = jnp.einsum("bkf,fo->bko", xs, lp["W"].astype(xs.dtype))
    Wh = Wh.reshape(B, 1 + K, n_heads, -1)
    e_src = jnp.einsum("bkhf,hf->bkh", Wh, lp["a_src"].astype(xs.dtype))
    e_dst = jnp.einsum("bhf,hf->bh", Wh[:, 0], lp["a_dst"].astype(xs.dtype))
    e = jax.nn.leaky_relu(e_src + e_dst[:, None], negative_slope)
    alpha = jax.nn.softmax(e.astype(jnp.float32), axis=1).astype(xs.dtype)
    out = jnp.einsum("bkh,bkhf->bhf", alpha, Wh)
    if concat:
        return out.reshape(B, -1)
    return jnp.mean(out, axis=1)


def forward_sampled(params: dict, feats: jax.Array, roots: jax.Array,
                    nbr1: jax.Array, nbr2: jax.Array, cfg: GNNConfig
                    ) -> jax.Array:
    """2-layer GAT over a GraphSAGE-sampled block.

    feats: (N, d_feat) sharded feature store; roots: (B,);
    nbr1: (B, f1) level-1 neighbours; nbr2: (B·(1+f1), f2) level-2
    neighbours of [roots ++ flattened nbr1]."""
    B, f1 = nbr1.shape
    frontier = jnp.concatenate([roots[:, None], nbr1], axis=1).reshape(-1)
    x_front = feats[frontier]                            # (B(1+f1), F)
    x_n2 = feats[nbr2]                                   # (B(1+f1), f2, F)
    h1 = jax.nn.elu(gat_layer_fanout(x_front, x_n2, params["l1"],
                                     cfg.n_heads,
                                     negative_slope=cfg.negative_slope))
    h1 = h1.reshape(B, 1 + f1, -1)
    return gat_layer_fanout(h1[:, 0], h1[:, 1:], params["l2"], cfg.n_heads,
                            negative_slope=cfg.negative_slope, concat=False)


# ---------------------------------------------------------------------------
# Losses / readouts
# ---------------------------------------------------------------------------

def node_xent(logits: jax.Array, labels: jax.Array, mask: jax.Array
              ) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return -jnp.sum(gold * m) / jnp.maximum(jnp.sum(m), 1.0)


def graph_readout(node_logits: jax.Array, graph_ids: jax.Array,
                  n_graphs: int) -> jax.Array:
    """Mean-pool node logits per graph (block-diagonal molecule batch)."""
    s = jax.ops.segment_sum(node_logits.astype(jnp.float32), graph_ids,
                            num_segments=n_graphs)
    c = jax.ops.segment_sum(jnp.ones((node_logits.shape[0],), jnp.float32),
                            graph_ids, num_segments=n_graphs)
    return s / jnp.maximum(c[:, None], 1.0)


# ---------------------------------------------------------------------------
# Per-shape loss entry points + dry-run inputs
# ---------------------------------------------------------------------------

def loss_full(params, batch, cfg: GNNConfig) -> jax.Array:
    logits = forward_segment(params, batch["feats"], batch["edge_src"],
                             batch["edge_dst"], cfg)
    return node_xent(logits, batch["labels"], batch["mask"])


def loss_sampled(params, batch, cfg: GNNConfig) -> jax.Array:
    logits = forward_sampled(params, batch["feats"], batch["roots"],
                             batch["nbr1"], batch["nbr2"], cfg)
    return node_xent(logits, batch["labels"],
                     jnp.ones(logits.shape[0], jnp.float32))


def loss_batched(params, batch, cfg: GNNConfig) -> jax.Array:
    """Block-diagonal molecule batch: graph classification."""
    feats = batch["feats"]                               # (B, n, F)
    B, n, F = feats.shape
    flat = feats.reshape(B * n, F)
    offs = (jnp.arange(B, dtype=jnp.int32) * n)[:, None]
    src = (batch["edge_src"] + offs).reshape(-1)
    dst = (batch["edge_dst"] + offs).reshape(-1)
    logits = forward_segment(params, flat, src, dst, cfg)
    gids = jnp.repeat(jnp.arange(B, dtype=jnp.int32), n)
    glogits = graph_readout(logits, gids, B)
    return node_xent(glogits, batch["labels"],
                     jnp.ones((B,), jnp.float32))


LOSS_BY_KIND = {
    "train_full": loss_full,
    "train_sampled": loss_sampled,
    "train_batched": loss_batched,
}


def input_structs(cfg: GNNConfig, shape: ShapeSpec) -> dict[str, Any]:
    from repro.configs.base import pad_to_shard
    f32, i32 = jnp.float32, jnp.int32
    d = shape.dim("d_feat")
    if shape.kind == "train_full":
        # Node/edge counts pad to the shard boundary; padding edges are
        # self-loops on the dead tail nodes (mask excludes them from loss).
        N = pad_to_shard(shape.dim("n_nodes"))
        E = pad_to_shard(shape.dim("n_edges") + shape.dim("n_nodes"))
        return {
            "feats": jax.ShapeDtypeStruct((N, d), f32),
            "edge_src": jax.ShapeDtypeStruct((E,), i32),
            "edge_dst": jax.ShapeDtypeStruct((E,), i32),
            "labels": jax.ShapeDtypeStruct((N,), i32),
            "mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
        }
    if shape.kind == "train_sampled":
        N = pad_to_shard(shape.dim("n_nodes"))
        B = shape.dim("batch_nodes")
        f1, f2 = shape.dim("fanout")
        return {
            "feats": jax.ShapeDtypeStruct((N, d), f32),
            "roots": jax.ShapeDtypeStruct((B,), i32),
            "nbr1": jax.ShapeDtypeStruct((B, f1), i32),
            "nbr2": jax.ShapeDtypeStruct((B * (1 + f1), f2), i32),
            "labels": jax.ShapeDtypeStruct((B,), i32),
        }
    if shape.kind == "train_batched":
        B = shape.dim("batch")
        n, e = shape.dim("n_nodes"), shape.dim("n_edges")
        return {
            "feats": jax.ShapeDtypeStruct((B, n, d), f32),
            "edge_src": jax.ShapeDtypeStruct((B, e + n), i32),
            "edge_dst": jax.ShapeDtypeStruct((B, e + n), i32),
            "labels": jax.ShapeDtypeStruct((B,), i32),
        }
    raise ValueError(f"unknown GNN shape kind {shape.kind}")
