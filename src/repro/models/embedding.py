"""Sharded sparse-embedding substrate for the recsys family.

JAX has no native EmbeddingBag or CSR sparse; per the assignment this is
built as part of the system: one concatenated table per model (fields laid
out back-to-back with static offsets), plain ``jnp.take`` for one-hot
fields, and gather + masked-sum (``segment_sum`` for the ragged variant) for
multi-hot bags.  Table rows shard over *all* mesh axes
(P(('pod','data','model'), None)) — tables dominate recsys memory and this
is the row-wise sharding production parameter servers use; GSPMD partitions
the gathers and the scatter-add gradients.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init


def field_offsets(vocab_sizes: tuple[int, ...]) -> np.ndarray:
    """Static start offset of each field inside the concatenated table."""
    return np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)


def init_table(key: jax.Array, vocab_sizes: tuple[int, ...], dim: int,
               dtype=jnp.float32, scale: float | None = None) -> jax.Array:
    """Rows padded to the 512 shard boundary (see configs.base.pad_to_shard)
    so row-sharding over any mesh-axis subset divides evenly."""
    from repro.configs.base import pad_to_shard
    total = pad_to_shard(int(sum(vocab_sizes)))
    return normal_init(key, (total, dim), scale or dim ** -0.5, dtype)


def lookup(table: jax.Array, idx: jax.Array,
           offsets: np.ndarray) -> jax.Array:
    """One-hot fields: idx (..., F) of per-field ids -> (..., F, dim)."""
    flat = idx + jnp.asarray(offsets, idx.dtype)
    return jnp.take(table, flat, axis=0)


def embedding_bag(table: jax.Array, idx: jax.Array, mask: jax.Array,
                  offsets: np.ndarray | None = None,
                  combiner: str = "sum") -> jax.Array:
    """Multi-hot bags: idx (..., F, H) with validity ``mask`` -> (..., F, dim).

    gather + masked reduce == torch ``nn.EmbeddingBag`` semantics.
    """
    if offsets is not None:
        idx = idx + jnp.asarray(offsets, idx.dtype)[..., :, None]
    emb = jnp.take(table, idx, axis=0)                    # (..., F, H, dim)
    m = mask.astype(emb.dtype)[..., None]
    s = jnp.sum(emb * m, axis=-2)
    if combiner == "sum":
        return s
    if combiner == "mean":
        return s / jnp.maximum(jnp.sum(m, axis=-2), 1.0)
    raise ValueError(f"unknown combiner {combiner!r}")


def embedding_bag_ragged(table: jax.Array, flat_idx: jax.Array,
                         segment_ids: jax.Array, n_bags: int,
                         weights: jax.Array | None = None) -> jax.Array:
    """CSR-style ragged bags: flat_idx (T,), segment_ids (T,) -> (n_bags, dim)
    via gather + ``segment_sum`` (the jax-native EmbeddingBag)."""
    emb = jnp.take(table, flat_idx, axis=0)
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    return jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
