"""Recsys family: BST, xDeepFM (CIN), AutoInt, two-tower retrieval.

All four share the sharded embedding substrate (``repro.models.embedding``):
huge concatenated id tables (rows sharded over every mesh axis) feeding a
small dense interaction network.  The CTR models (BST / xDeepFM / AutoInt)
emit a sigmoid logit trained with BCE; the two-tower model trains with
in-batch sampled softmax and serves both pairwise scoring and 1M-candidate
retrieval (a single sharded matmul + top-k, per the assignment's
"batched-dot, not a loop").
"""
from __future__ import annotations

from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig, ShapeSpec
from repro.models import embedding as emb
from repro.models.layers import fan_in_init, normal_init

# Multi-hot bag attached to field 0 of the CTR models (exercises the
# EmbeddingBag path; e.g. "recent categories" list feature).
MULTI_HOT = 8


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _mlp_params(key, dims: tuple[int, ...], d_in: int, dt,
                d_out: int | None = 1) -> list[dict]:
    layers = []
    ks = jax.random.split(key, len(dims) + 1)
    prev = d_in
    for i, d in enumerate(dims):
        layers.append({"w": fan_in_init(ks[i], (prev, d), dt),
                       "b": jnp.zeros((d,), dt)})
        prev = d
    if d_out is not None:
        layers.append({"w": fan_in_init(ks[-1], (prev, d_out), dt),
                       "b": jnp.zeros((d_out,), dt)})
    return layers


def _mlp(x: jax.Array, layers: list[dict], act=jax.nn.relu,
         final_act: bool = False) -> jax.Array:
    for i, lp in enumerate(layers):
        x = jnp.einsum("...d,df->...f", x, lp["w"].astype(x.dtype)) + \
            lp["b"].astype(x.dtype)
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def bce_with_logits(logit: jax.Array, label: jax.Array) -> jax.Array:
    z, y = logit.astype(jnp.float32), label.astype(jnp.float32)
    per = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return jnp.mean(per)


def _ctr_embed(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """(B, n_sparse, dim) field embeddings (+ multi-hot bag into field 0)."""
    offs = field_offsets_np(cfg)
    e = emb.lookup(params["table"], batch["sparse_idx"], offs)
    if "multi_idx" in batch:
        bag = emb.embedding_bag(params["table"],
                                batch["multi_idx"][:, None, :],
                                batch["multi_mask"][:, None, :])
        e = e.at[:, 0].add(bag[:, 0].astype(e.dtype))
    return e


def field_offsets_np(cfg: RecsysConfig) -> np.ndarray:
    return emb.field_offsets(cfg.field_vocab_sizes)


# ---------------------------------------------------------------------------
# xDeepFM
# ---------------------------------------------------------------------------

def _init_xdeepfm(key, cfg: RecsysConfig, dt) -> dict:
    ks = jax.random.split(key, 8)
    m, D = cfg.n_sparse, cfg.embed_dim
    cin_ws, prev = [], m
    for i, h in enumerate(cfg.cin_layers):
        cin_ws.append(fan_in_init(ks[3 + i % 3], (prev * m, h), dt))
        prev = h
    return {
        "table": emb.init_table(ks[0], cfg.field_vocab_sizes, D, dt),
        "lin_table": emb.init_table(ks[1], cfg.field_vocab_sizes, 1, dt),
        "dense_w": fan_in_init(ks[2], (cfg.n_dense, 1), dt),
        "cin": cin_ws,
        "cin_out": fan_in_init(ks[6], (int(sum(cfg.cin_layers)), 1), dt),
        "dnn": _mlp_params(ks[7], cfg.mlp_dims, m * D + cfg.n_dense, dt),
    }


def _fwd_xdeepfm(params, batch, cfg: RecsysConfig) -> jax.Array:
    e = _ctr_embed(params, batch, cfg)                   # (B, m, D)
    B, m, D = e.shape
    # linear (wide) branch
    lin = jnp.sum(emb.lookup(params["lin_table"], batch["sparse_idx"],
                             field_offsets_np(cfg))[..., 0], axis=1)
    lin = lin + _mlp(batch["dense"].astype(e.dtype),
                     [{"w": params["dense_w"],
                       "b": jnp.zeros((1,), e.dtype)}])[..., 0]
    # CIN branch
    x0, xk, pooled = e, e, []
    for W in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)          # outer product
        z = z.reshape(B, -1, D)
        xk = jnp.einsum("bpd,ph->bhd", z, W.astype(e.dtype))
        pooled.append(jnp.sum(xk, axis=-1))              # (B, H_k)
    cin_logit = _mlp(jnp.concatenate(pooled, axis=-1),
                     [{"w": params["cin_out"],
                       "b": jnp.zeros((1,), e.dtype)}])[..., 0]
    # DNN branch
    dnn_in = jnp.concatenate([e.reshape(B, m * D),
                              batch["dense"].astype(e.dtype)], axis=-1)
    dnn_logit = _mlp(dnn_in, params["dnn"])[..., 0]
    return lin.astype(jnp.float32) + cin_logit.astype(jnp.float32) + \
        dnn_logit.astype(jnp.float32)


# ---------------------------------------------------------------------------
# AutoInt
# ---------------------------------------------------------------------------

def _init_autoint(key, cfg: RecsysConfig, dt) -> dict:
    ks = jax.random.split(key, 4 + cfg.n_attn_layers)
    D, A = cfg.embed_dim, cfg.d_attn
    layers, d_in = [], D
    for i in range(cfg.n_attn_layers):
        kq, kk, kv, kr = jax.random.split(ks[3 + i], 4)
        layers.append({"wq": fan_in_init(kq, (d_in, A), dt),
                       "wk": fan_in_init(kk, (d_in, A), dt),
                       "wv": fan_in_init(kv, (d_in, A), dt),
                       "wr": fan_in_init(kr, (d_in, A), dt)})
        d_in = A
    n_tok = cfg.n_sparse + cfg.n_dense
    return {
        "table": emb.init_table(ks[0], cfg.field_vocab_sizes, D, dt),
        "dense_emb": normal_init(ks[1], (cfg.n_dense, D), D ** -0.5, dt),
        "attn": layers,
        "out": fan_in_init(ks[2], (n_tok * A, 1), dt),
    }


def _fwd_autoint(params, batch, cfg: RecsysConfig) -> jax.Array:
    e = _ctr_embed(params, batch, cfg)                   # (B, m, D)
    dense_tok = batch["dense"].astype(e.dtype)[..., None] * \
        params["dense_emb"].astype(e.dtype)[None]        # (B, 13, D)
    x = jnp.concatenate([e, dense_tok], axis=1)          # (B, T, D)
    H = cfg.n_attn_heads
    for lp in params["attn"]:
        q = jnp.einsum("btd,da->bta", x, lp["wq"].astype(x.dtype))
        k = jnp.einsum("btd,da->bta", x, lp["wk"].astype(x.dtype))
        v = jnp.einsum("btd,da->bta", x, lp["wv"].astype(x.dtype))
        B, T, A = q.shape
        hd = A // H
        q = q.reshape(B, T, H, hd)
        k = k.reshape(B, T, H, hd)
        v = v.reshape(B, T, H, hd)
        s = jnp.einsum("bthd,bshd->bhts", q, k,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhts,bshd->bthd", a, v).reshape(B, T, A)
        res = jnp.einsum("btd,da->bta", x, lp["wr"].astype(x.dtype))
        x = jax.nn.relu(o + res)
    B = x.shape[0]
    return _mlp(x.reshape(B, -1), [{"w": params["out"],
                                    "b": jnp.zeros((1,), x.dtype)}]
                )[..., 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# BST (Behavior Sequence Transformer)
# ---------------------------------------------------------------------------

def _init_bst(key, cfg: RecsysConfig, dt) -> dict:
    ks = jax.random.split(key, 10)
    D = cfg.embed_dim
    seq = cfg.seq_len + 1                                # history + target
    blocks = []
    for i in range(cfg.n_blocks):
        kq, kk, kv, ko, k1, k2 = jax.random.split(ks[4 + i], 6)
        blocks.append({
            "wq": fan_in_init(kq, (D, D), dt),
            "wk": fan_in_init(kk, (D, D), dt),
            "wv": fan_in_init(kv, (D, D), dt),
            "wo": fan_in_init(ko, (D, D), dt),
            "ffn_in": fan_in_init(k1, (D, 4 * D), dt),
            "ffn_out": fan_in_init(k2, (4 * D, D), dt),
        })
    d_flat = seq * D + cfg.n_sparse * D
    return {
        "item_table": emb.init_table(ks[0], (cfg.item_vocab,), D, dt),
        "pos_emb": normal_init(ks[1], (seq, D), D ** -0.5, dt),
        "other_table": emb.init_table(ks[2], cfg.field_vocab_sizes, D, dt),
        "blocks": blocks,
        "mlp": _mlp_params(ks[3], cfg.mlp_dims, d_flat, dt),
    }


def _fwd_bst(params, batch, cfg: RecsysConfig) -> jax.Array:
    seq_ids = jnp.concatenate([batch["hist"], batch["target"][:, None]],
                              axis=1)                    # (B, S+1)
    x = jnp.take(params["item_table"], seq_ids, axis=0)
    x = x + params["pos_emb"].astype(x.dtype)[None]
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    for bp in params["blocks"]:
        q = jnp.einsum("bsd,df->bsf", x, bp["wq"].astype(x.dtype)).reshape(
            B, S, H, hd)
        k = jnp.einsum("bsd,df->bsf", x, bp["wk"].astype(x.dtype)).reshape(
            B, S, H, hd)
        v = jnp.einsum("bsd,df->bsf", x, bp["wv"].astype(x.dtype)).reshape(
            B, S, H, hd)
        s = jnp.einsum("bshd,bthd->bhst", q, k,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthd->bshd", a, v).reshape(B, S, D)
        x = x + jnp.einsum("bsd,df->bsf", o, bp["wo"].astype(x.dtype))
        h = jax.nn.leaky_relu(jnp.einsum(
            "bsd,df->bsf", x, bp["ffn_in"].astype(x.dtype)))
        x = x + jnp.einsum("bsf,fd->bsd", h, bp["ffn_out"].astype(x.dtype))
    other = emb.lookup(params["other_table"], batch["sparse_idx"],
                       field_offsets_np(cfg))            # (B, F, D)
    flat = jnp.concatenate([x.reshape(B, -1), other.reshape(B, -1)], axis=-1)
    return _mlp(flat, params["mlp"],
                act=jax.nn.leaky_relu)[..., 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Two-tower retrieval
# ---------------------------------------------------------------------------

_ID_DIM = 128
_FIELD_DIM = 32
_N_USER_FIELDS = 4
_N_ITEM_FIELDS = 2


def _init_two_tower(key, cfg: RecsysConfig, dt) -> dict:
    ks = jax.random.split(key, 6)
    u_in = _ID_DIM + _N_USER_FIELDS * _FIELD_DIM
    i_in = _ID_DIM + _N_ITEM_FIELDS * _FIELD_DIM
    return {
        "user_table": emb.init_table(ks[0], (cfg.user_vocab,), _ID_DIM, dt),
        "item_table": emb.init_table(ks[1], (cfg.item_vocab,), _ID_DIM, dt),
        "field_table": emb.init_table(ks[2], cfg.field_vocab_sizes,
                                      _FIELD_DIM, dt),
        "user_mlp": _mlp_params(ks[3], cfg.tower_mlp[:-1], u_in, dt,
                                d_out=cfg.tower_mlp[-1]),
        "item_mlp": _mlp_params(ks[4], cfg.tower_mlp[:-1], i_in, dt,
                                d_out=cfg.tower_mlp[-1]),
        "log_tau": jnp.zeros((), jnp.float32),
    }


def _tower(x: jax.Array, layers: list[dict]) -> jax.Array:
    h = _mlp(x, layers)
    return h / jnp.maximum(jnp.linalg.norm(h.astype(jnp.float32), axis=-1,
                                           keepdims=True), 1e-6).astype(
        h.dtype)


def user_embed(params, user_id, user_fields, cfg: RecsysConfig) -> jax.Array:
    offs = field_offsets_np(cfg)[:_N_USER_FIELDS]
    uid = jnp.take(params["user_table"], user_id, axis=0)
    uf = emb.lookup(params["field_table"], user_fields, offs)
    x = jnp.concatenate([uid, uf.reshape(uf.shape[0], -1)], axis=-1)
    return _tower(x, params["user_mlp"])


def item_embed(params, item_id, item_fields, cfg: RecsysConfig) -> jax.Array:
    offs = field_offsets_np(cfg)[_N_USER_FIELDS:
                                 _N_USER_FIELDS + _N_ITEM_FIELDS]
    iid = jnp.take(params["item_table"], item_id, axis=0)
    itf = emb.lookup(params["field_table"], item_fields, offs)
    x = jnp.concatenate([iid, itf.reshape(itf.shape[0], -1)], axis=-1)
    return _tower(x, params["item_mlp"])


def _fwd_two_tower(params, batch, cfg: RecsysConfig) -> jax.Array:
    """Pairwise scores (serve kind)."""
    u = user_embed(params, batch["user_id"], batch["user_fields"], cfg)
    i = item_embed(params, batch["item_id"], batch["item_fields"], cfg)
    return jnp.sum(u.astype(jnp.float32) * i.astype(jnp.float32), axis=-1)


def two_tower_loss(params, batch, cfg: RecsysConfig) -> jax.Array:
    """In-batch sampled softmax (Yi et al. RecSys'19; logQ correction is a
    no-op under the synthetic uniform negatives and is omitted)."""
    u = user_embed(params, batch["user_id"], batch["user_fields"], cfg)
    i = item_embed(params, batch["item_id"], batch["item_fields"], cfg)
    tau = jnp.exp(params["log_tau"]) + 0.05
    logits = jnp.einsum("bd,cd->bc", u.astype(jnp.float32),
                        i.astype(jnp.float32)) / tau
    B = logits.shape[0]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.diagonal(logp))


def retrieve(params, batch, cfg: RecsysConfig, top_k: int = 100
             ) -> tuple[jax.Array, jax.Array]:
    """1 query vs n_candidates: one sharded matmul + top-k."""
    u = user_embed(params, batch["user_id"], batch["user_fields"], cfg)
    iemb = item_embed(params, batch["cand_ids"], batch["cand_fields"], cfg)
    scores = jnp.einsum("qd,cd->qc", u.astype(jnp.float32),
                        iemb.astype(jnp.float32))
    return jax.lax.top_k(scores, top_k)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

_INIT = {"xdeepfm": _init_xdeepfm, "autoint": _init_autoint,
         "bst": _init_bst, "two_tower": _init_two_tower}
_FWD = {"xdeepfm": _fwd_xdeepfm, "autoint": _fwd_autoint, "bst": _fwd_bst,
        "two_tower": _fwd_two_tower}


def init_params(key: jax.Array, cfg: RecsysConfig) -> dict:
    return _INIT[cfg.variant](key, cfg, jnp.dtype(cfg.dtype))


def forward(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    return _FWD[cfg.variant](params, batch, cfg)


def loss(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    if cfg.variant == "two_tower":
        return two_tower_loss(params, batch, cfg)
    return bce_with_logits(forward(params, batch, cfg), batch["label"])


def input_structs(cfg: RecsysConfig, shape: ShapeSpec) -> dict[str, Any]:
    f32, i32 = jnp.float32, jnp.int32
    B = shape.dim("batch")
    if cfg.variant == "two_tower":
        if shape.kind == "retrieval":
            C = shape.dim("n_candidates")
            return {
                "user_id": jax.ShapeDtypeStruct((B,), i32),
                "user_fields": jax.ShapeDtypeStruct((B, _N_USER_FIELDS), i32),
                "cand_ids": jax.ShapeDtypeStruct((C,), i32),
                "cand_fields": jax.ShapeDtypeStruct((C, _N_ITEM_FIELDS), i32),
            }
        d = {
            "user_id": jax.ShapeDtypeStruct((B,), i32),
            "user_fields": jax.ShapeDtypeStruct((B, _N_USER_FIELDS), i32),
            "item_id": jax.ShapeDtypeStruct((B,), i32),
            "item_fields": jax.ShapeDtypeStruct((B, _N_ITEM_FIELDS), i32),
        }
        if shape.kind == "train":
            d["label"] = jax.ShapeDtypeStruct((B,), f32)
        return d

    if shape.kind == "retrieval":
        # CTR models score 1M candidate items under one user context by
        # broadcasting the user/context fields.
        B = shape.dim("n_candidates")
    d: dict[str, Any] = {"sparse_idx": jax.ShapeDtypeStruct(
        (B, cfg.n_sparse), i32)}
    if cfg.n_dense:
        d["dense"] = jax.ShapeDtypeStruct((B, cfg.n_dense), f32)
    if cfg.variant == "xdeepfm":
        d["multi_idx"] = jax.ShapeDtypeStruct((B, MULTI_HOT), i32)
        d["multi_mask"] = jax.ShapeDtypeStruct((B, MULTI_HOT), jnp.bool_)
    if cfg.variant == "bst":
        d["hist"] = jax.ShapeDtypeStruct((B, cfg.seq_len), i32)
        d["target"] = jax.ShapeDtypeStruct((B,), i32)
    if shape.kind == "train":
        d["label"] = jax.ShapeDtypeStruct((B,), f32)
    return d
