from repro.models import attention, cf, embedding, gnn, layers, moe, recsys
from repro.models import transformer

__all__ = ["attention", "cf", "embedding", "gnn", "layers", "moe", "recsys",
           "transformer"]
