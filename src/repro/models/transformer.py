"""Decoder-only LM family: dense + MoE, GQA/MQA, sliding-window/global mix.

Design points (see DESIGN.md §5):
  * layer params are stacked (L, ...) and the block runs under
    ``lax.scan`` (+ ``jax.checkpoint``) so HLO size, compile time and
    activation memory stay O(1) in depth;
  * per-layer attention windows are data (an (L,) int32 vector: W for local
    layers, a huge sentinel for global ones) so the local/global mix runs
    through one scanned block;
  * the LM head loss is computed in sequence chunks under an inner scan so
    the (B, S, V) logits tensor never materialises (vocab up to 262k);
  * decode is an unrolled layer loop with a ring-buffer cache (size W) for
    local layers and a full cache for global layers;
  * optional ``with_sharding_constraint`` hooks thread the activation
    sharding plan through without making the model depend on a mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSpec
from repro.models.attention import gqa_attention
from repro.models.layers import (apply_rope, fan_in_init, ffn, normal_init,
                                 rms_norm)
from repro.models.moe import moe_ffn
from repro.models.moe_ep import moe_ffn_ep

GLOBAL_WINDOW = 1 << 30


class LMShardingHooks(NamedTuple):
    """PartitionSpecs applied via with_sharding_constraint (None = no-op)."""

    acts: Any = None        # (B, S, d) between blocks
    logits: Any = None      # (B, chunk, V) inside the loss scan
    moe_tokens: Any = None  # (G, gs, d) token groups + dispatch buffer
    moe_experts: Any = None  # (G, E, C, f) expert-sharded buffers
    moe_ep: Any = None      # MoEEPInfo -> shard_map expert parallelism


def _constrain(x: jax.Array, spec) -> jax.Array:
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def is_global_layer(cfg: LMConfig, layer: int) -> bool:
    if cfg.window is None:
        return True
    if cfg.global_every is None:
        return False
    return (layer + 1) % cfg.global_every == 0


def layer_windows(cfg: LMConfig) -> jnp.ndarray:
    """(L,) int32 attention window per layer (sentinel = global)."""
    return jnp.asarray(
        [GLOBAL_WINDOW if is_global_layer(cfg, l) else cfg.window
         for l in range(cfg.n_layers)], jnp.int32)


def _glu_factor(cfg: LMConfig) -> int:
    return 2 if cfg.act in ("swiglu", "geglu") else 1


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: LMConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, L = cfg.d_model, cfg.n_layers
    gf = _glu_factor(cfg)
    ks = jax.random.split(key, 16)
    layers: dict[str, jax.Array] = {
        "attn_norm": jnp.zeros((L, d), dt),
        "mlp_norm": jnp.zeros((L, d), dt),
        "wq": fan_in_init(ks[0], (L, d, cfg.q_dim), dt),
        "wk": fan_in_init(ks[1], (L, d, cfg.kv_dim), dt),
        "wv": fan_in_init(ks[2], (L, d, cfg.kv_dim), dt),
        "wo": normal_init(ks[3], (L, cfg.q_dim, d),
                          (cfg.q_dim ** -0.5) / (2 * L) ** 0.5, dt),
    }
    if cfg.moe is not None:
        m = cfg.moe
        layers["router"] = normal_init(ks[4], (L, d, m.n_experts),
                                       d ** -0.5, jnp.float32)
        layers["w_in_e"] = fan_in_init(
            ks[5], (L, m.n_experts, d, gf * m.d_ff_expert), dt)
        layers["w_out_e"] = normal_init(
            ks[6], (L, m.n_experts, m.d_ff_expert, d),
            (m.d_ff_expert ** -0.5) / (2 * L) ** 0.5, dt)
        if m.n_shared:
            layers["w_in_sh"] = fan_in_init(
                ks[7], (L, d, gf * m.n_shared * m.d_ff_expert), dt)
            layers["w_out_sh"] = normal_init(
                ks[8], (L, m.n_shared * m.d_ff_expert, d),
                (m.d_ff_expert ** -0.5) / (2 * L) ** 0.5, dt)
    else:
        layers["w_in"] = fan_in_init(ks[5], (L, d, gf * cfg.d_ff), dt)
        layers["w_out"] = normal_init(ks[6], (L, cfg.d_ff, d),
                                      (cfg.d_ff ** -0.5) / (2 * L) ** 0.5, dt)
    params = {
        "embed": normal_init(ks[9], (cfg.vocab_size, d), 1.0, dt),
        "layers": layers,
        "final_norm": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = fan_in_init(ks[10], (d, cfg.vocab_size), dt)
    return params


def param_structs(cfg: LMConfig):
    """ShapeDtypeStruct pytree of the params (no allocation) — dry-run."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Blocks / forward
# ---------------------------------------------------------------------------

def _attention_sublayer(x: jax.Array, lp: dict, cfg: LMConfig,
                        positions: jax.Array, win,
                        unroll: bool = False) -> jax.Array:
    B, S, d = x.shape
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, lp["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dq->bsq", h, lp["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dq->bsq", h, lp["wv"].astype(h.dtype))
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = gqa_attention(q, k, v, positions, positions, window=win,
                        unroll=unroll)
    out = out.reshape(B, S, cfg.q_dim)
    return jnp.einsum("bsq,qd->bsd", out, lp["wo"].astype(out.dtype))


def _ffn_sublayer(x: jax.Array, lp: dict, cfg: LMConfig,
                  hooks: LMShardingHooks = LMShardingHooks()
                  ) -> tuple[jax.Array, jax.Array]:
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        shared = ((lp["w_in_sh"], lp["w_out_sh"])
                  if cfg.moe.n_shared else None)
        if hooks.moe_ep is not None:
            y, aux = moe_ffn_ep(h, lp["router"], lp["w_in_e"],
                                lp["w_out_e"], cfg.moe, cfg.act,
                                hooks.moe_ep)
            if shared is not None:
                y = y + ffn(h, shared[0], shared[1], cfg.act)
            return y, aux
        return moe_ffn(h, lp["router"], lp["w_in_e"], lp["w_out_e"], shared,
                       cfg.moe, cfg.act, tokens_spec=hooks.moe_tokens,
                       experts_spec=hooks.moe_experts)
    return ffn(h, lp["w_in"], lp["w_out"], cfg.act), jnp.float32(0.0)


def _block(x: jax.Array, lp: dict, win, cfg: LMConfig,
           positions: jax.Array, hooks: LMShardingHooks,
           unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    x = x + _attention_sublayer(x, lp, cfg, positions, win, unroll)
    y, aux = _ffn_sublayer(x, lp, cfg, hooks)
    x = _constrain(x + y, hooks.acts)
    return x, aux


def embed_tokens(params: dict, tokens: jax.Array, cfg: LMConfig
                 ) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def forward(params: dict, tokens: jax.Array, cfg: LMConfig,
            hooks: LMShardingHooks = LMShardingHooks(),
            unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (hidden (B, S, d) pre-final-norm, mean aux loss).

    ``unroll`` fully unrolls the layer scan (and inner chunk scans) so the
    dry-run's cost analysis and collective census see every iteration (XLA
    counts while bodies once)."""
    S = tokens.shape[1]
    x = _constrain(embed_tokens(params, tokens, cfg), hooks.acts)
    positions = jnp.arange(S, dtype=jnp.int32)
    wins = layer_windows(cfg)

    block = partial(_block, cfg=cfg, positions=positions, hooks=hooks,
                    unroll=unroll)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, xs):
        lp, win = xs
        return block(carry, lp, win)

    x, auxs = jax.lax.scan(body, x, (params["layers"], wins),
                           unroll=cfg.n_layers if unroll else 1)
    return x, jnp.mean(auxs)


def unembed_weight(params: dict, cfg: LMConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def lm_loss(params: dict, tokens: jax.Array, cfg: LMConfig,
            hooks: LMShardingHooks = LMShardingHooks(),
            loss_chunk: int = 512, unroll: bool = False) -> jax.Array:
    """Next-token cross entropy, computed in sequence chunks so the full
    (B, S, V) logits tensor never exists."""
    B, S = tokens.shape
    h, aux = forward(params, tokens, cfg, hooks, unroll)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    W = unembed_weight(params, cfg)

    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = (jnp.arange(S) < S - 1).astype(jnp.float32)[None, :]

    cs = min(loss_chunk, S)
    n_chunks = S // cs
    assert n_chunks * cs == S
    hc = h.reshape(B, n_chunks, cs, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, cs).transpose(1, 0, 2)
    mc = mask.reshape(1, n_chunks, cs).transpose(1, 0, 2)

    def chunk_loss(carry, xs):
        hcj, lcj, mcj = xs
        logits = jnp.einsum("bsd,dv->bsv", hcj, W.astype(hcj.dtype),
                            preferred_element_type=jnp.float32)
        logits = _constrain(logits, hooks.logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lcj[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return carry + jnp.sum((lse - gold) * mcj), ()

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hc, lc, mc),
                            unroll=n_chunks if unroll else 1)
    loss = total / jnp.maximum(jnp.sum(mask) * B, 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def _global_local_split(cfg: LMConfig) -> tuple[list[int], list[int]]:
    g = [l for l in range(cfg.n_layers) if is_global_layer(cfg, l)]
    loc = [l for l in range(cfg.n_layers) if not is_global_layer(cfg, l)]
    return g, loc


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Decode-cache pytree: full (S_max) cache for global layers, ring
    buffer (W) for local layers, plus the ring's written-position vector."""
    dt = jnp.dtype(dtype or cfg.dtype)
    g, loc = _global_local_split(cfg)
    cache = {
        "kg": jnp.zeros((len(g), batch, max_len, cfg.n_kv_heads,
                         cfg.head_dim), dt),
        "vg": jnp.zeros((len(g), batch, max_len, cfg.n_kv_heads,
                         cfg.head_dim), dt),
    }
    if loc:
        W = cfg.window
        cache["kl"] = jnp.zeros((len(loc), batch, W, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
        cache["vl"] = jnp.zeros((len(loc), batch, W, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
        cache["ring_pos"] = jnp.full((W,), -1, jnp.int32)
    return cache


def cache_structs(cfg: LMConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def prefill(params: dict, tokens: jax.Array, cfg: LMConfig,
            hooks: LMShardingHooks = LMShardingHooks(),
            unroll: bool = False) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also returns the decode cache.
    Returns (last-position logits (B, V), cache)."""
    B, S = tokens.shape
    x = _constrain(embed_tokens(params, tokens, cfg), hooks.acts)
    positions = jnp.arange(S, dtype=jnp.int32)
    wins = layer_windows(cfg)

    def body(carry, xs):
        lp, win = xs
        h = rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
        k = jnp.einsum("bsd,dq->bsq", h, lp["wk"].astype(h.dtype)).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        v = jnp.einsum("bsd,dq->bsq", h, lp["wv"].astype(h.dtype)).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        k = apply_rope(k, positions, cfg.rope_theta)
        q = jnp.einsum("bsd,dq->bsq", h, lp["wq"].astype(h.dtype)).reshape(
            B, S, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, positions, cfg.rope_theta)
        out = gqa_attention(q, k, v, positions, positions, window=win,
                            unroll=unroll)
        x1 = carry + jnp.einsum("bsq,qd->bsd",
                                out.reshape(B, S, cfg.q_dim),
                                lp["wo"].astype(out.dtype))
        y, _aux = _ffn_sublayer(x1, lp, cfg, hooks)
        return _constrain(x1 + y, hooks.acts), (k, v)

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (k_all, v_all) = jax.lax.scan(body_fn, x, (params["layers"], wins),
                                     unroll=cfg.n_layers if unroll else 1)

    g, loc = _global_local_split(cfg)
    gidx = jnp.asarray(g, jnp.int32)
    cache = {"kg": k_all[gidx], "vg": v_all[gidx]}
    if loc:
        W = cfg.window
        lidx = jnp.asarray(loc, jnp.int32)
        pos_tail = jnp.arange(S - W, S, dtype=jnp.int32)
        slots = pos_tail % W
        ring_k = jnp.zeros((len(loc), B, W, cfg.n_kv_heads, cfg.head_dim),
                           k_all.dtype).at[:, :, slots].set(
            k_all[lidx][:, :, pos_tail])
        ring_v = jnp.zeros_like(ring_k).at[:, :, slots].set(
            v_all[lidx][:, :, pos_tail])
        cache.update(kl=ring_k, vl=ring_v,
                     ring_pos=jnp.zeros((W,), jnp.int32).at[slots].set(
                         pos_tail))
    h_last = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h_last,
                        unembed_weight(params, cfg).astype(h_last.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                pos: jax.Array, cfg: LMConfig,
                hooks: LMShardingHooks = LMShardingHooks()
                ) -> tuple[jax.Array, dict]:
    """One new token per sequence against the cache.

    tokens: (B, 1) int32; pos: () int32 — the position being written.
    Returns (logits (B, V), updated cache).  Layers are unrolled (decode HLO
    is tiny per layer; per-layer cache shapes differ local vs global).
    """
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)                # (B, 1, d)
    qpos = pos[None].astype(jnp.int32)
    g, loc = _global_local_split(cfg)
    g_of = {l: i for i, l in enumerate(g)}
    l_of = {l: i for i, l in enumerate(loc)}
    cache = dict(cache)
    S_max = cache["kg"].shape[2]
    if loc:
        W = cfg.window
        ring_pos = cache["ring_pos"].at[pos % W].set(pos)
        cache["ring_pos"] = ring_pos

    for l in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[l], params["layers"])
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", h, lp["wq"].astype(h.dtype)).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        k = jnp.einsum("bsd,dq->bsq", h, lp["wk"].astype(h.dtype)).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = jnp.einsum("bsd,dq->bsq", h, lp["wv"].astype(h.dtype)).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

        if is_global_layer(cfg, l):
            i = g_of[l]
            kg = jax.lax.dynamic_update_slice(
                cache["kg"], k[None].astype(cache["kg"].dtype),
                (i, 0, pos, 0, 0))
            vg = jax.lax.dynamic_update_slice(
                cache["vg"], v[None].astype(cache["vg"].dtype),
                (i, 0, pos, 0, 0))
            cache["kg"], cache["vg"] = kg, vg
            kpos = jnp.arange(S_max, dtype=jnp.int32)
            out = gqa_attention(q, kg[i], vg[i], qpos, kpos, window=None)
        else:
            i = l_of[l]
            slot = pos % W
            kl = jax.lax.dynamic_update_slice(
                cache["kl"], k[None].astype(cache["kl"].dtype),
                (i, 0, slot, 0, 0))
            vl = jax.lax.dynamic_update_slice(
                cache["vl"], v[None].astype(cache["vl"].dtype),
                (i, 0, slot, 0, 0))
            cache["kl"], cache["vl"] = kl, vl
            out = gqa_attention(q, kl[i], vl[i], qpos, ring_pos,
                                window=cfg.window)
        x = x + jnp.einsum("bsq,qd->bsd", out.reshape(B, 1, cfg.q_dim),
                           lp["wo"].astype(out.dtype))
        y, _aux = _ffn_sublayer(x, lp, cfg)
        x = x + y

    h_last = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h_last,
                        unembed_weight(params, cfg).astype(h_last.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache


# ---------------------------------------------------------------------------
# Dry-run input builders
# ---------------------------------------------------------------------------

def input_structs(cfg: LMConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B = shape.dim("global_batch")
    S = shape.dim("seq_len")
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "decode":
        return {
            "cache": cache_structs(cfg, B, S),
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(f"unknown LM shape kind {shape.kind}")
