"""Grouped-query attention with causal/sliding-window masking.

One implementation covers the three serving shapes:
  * train/prefill — online-softmax scan over KV chunks (flash-style, so 32k
    prefill never materialises an S×S score matrix);
  * decode (Sq == 1) — single block over the whole KV cache; with the cache's
    sequence axis sharded over the model mesh axis, GSPMD partitions the
    contraction + softmax into the flash-decoding split-KV pattern;
  * sliding-window layers — position-derived band mask; decode uses a ring
    buffer of size W with an explicit written-position vector.

Positions are explicit int32 vectors so causal, windowed, ring-buffer and
padding semantics all reduce to one mask expression:
  valid = (kpos >= 0) & (kpos <= qpos) & (window is None | kpos > qpos - W).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos: jax.Array, kpos: jax.Array, window: int | None
          ) -> jax.Array:
    """(..., Sq, Sk) bool validity mask from position vectors."""
    q = qpos[..., :, None].astype(jnp.int32)
    k = kpos[..., None, :].astype(jnp.int32)
    ok = (k >= 0) & (k <= q)
    if window is not None:
        ok &= k > q - window
    return ok


def _block_attn(q: jax.Array, k: jax.Array, v: jax.Array, qpos: jax.Array,
                kpos: jax.Array, window: int | None) -> jax.Array:
    """Unchunked reference path. q: (B,Sq,Hkv,G,hd); k,v: (B,Sk,Hkv,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    ok = _mask(qpos, kpos, window)[None, None, None]     # (1,1,1,Sq,Sk)
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out


def _chunked_attn(q: jax.Array, k: jax.Array, v: jax.Array, qpos: jax.Array,
                  kpos: jax.Array, window: int | None, chunk: int,
                  unroll: bool = False) -> jax.Array:
    """Online-softmax scan over KV chunks (flash-attention recurrence)."""
    B, Sq, Hkv, G, hd = q.shape
    Sk = k.shape[1]
    n_chunks = Sk // chunk
    assert n_chunks * chunk == Sk, (Sk, chunk)
    scale = hd ** -0.5
    kc = k.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(n_chunks, chunk)

    def step(carry, inp):
        acc, m, l = carry                               # acc: (B,Sq,Hkv,G,hd)
        kj, vj, pj = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        ok = _mask(qpos, pj, window)[None, None, None]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))     # (B,Hkv,G,Sq)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vj.dtype), vj)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + \
            pv.astype(jnp.float32)
        return (acc_new, m_new, l_new), ()

    init = (jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32),
            jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, Sq), jnp.float32))
    (acc, _m, l), _ = jax.lax.scan(step, init, (kc, vc, pc),
                                   unroll=n_chunks if unroll else 1)
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return acc / denom


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  qpos: jax.Array, kpos: jax.Array, *,
                  window: int | None = None, chunk: int = 2048,
                  unroll: bool = False) -> jax.Array:
    """q: (B,Sq,Hq,hd); k,v: (B,Sk,Hkv,hd); returns (B,Sq,Hq,hd).

    ``qpos``/``kpos``: (Sq,)/(Sk,) absolute positions (-1 = invalid slot).
    ``unroll`` unrolls the KV-chunk scan (dry-run cost-analysis accuracy:
    XLA counts while bodies once).
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    if Sq == 1 or k.shape[1] <= chunk:
        out = _block_attn(qg, k, v, qpos, kpos, window)
    else:
        out = _chunked_attn(qg, k, v, qpos, kpos, window, chunk, unroll)
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)
