"""The paper's CF system as a launchable architecture family.

Two step kinds (see ``repro/configs/twinsearch_cf.py``):

  * ``build``   — the traditional full similarity build: blocked cosine
    matmul (S sharded P(data, model), no collectives in the contraction
    since both operand row-blocks are fetched once) followed by a reshard to
    row-sharded layout and a local per-row sort.
  * ``onboard`` — the TwinSearch burst: k new users scanned through
    probe -> equal-range search -> mask intersect -> bounded verify -> copy,
    with the traditional matvec+sort as the per-user fallback branch.

At web scale the state is the dominant memory: sim lists shard rows over all
mesh axes; a new-user onboarding touches O(c·m + c·log n + c·n + s_max·m)
of it plus two scalar-sized collectives, which is the paper's O(n·m/125)
against the traditional O(n·m) — per pod, divided by the device count.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import CFConfig, ShapeSpec
from repro.core import twinsearch as ts
from repro.core.similarity import row_norms
from repro.core.types import CFState, SENTINEL, set0_cap


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def build_step(R: jax.Array, *, block_spec=None, rows_spec=None
               ) -> tuple[jax.Array, jax.Array]:
    """Full build: R (n, m) -> ascending sorted lists (vals f32, idx i32).

    ``block_spec``: PartitionSpec for the (n, n) similarity blocks
    (typically P('data', 'model')); ``rows_spec``: row-sharded layout for
    the sort (P(('data','model'), None)).
    """
    Rf = R.astype(jnp.float32)
    norms = jnp.maximum(row_norms(Rf), 1e-12)
    Rn = (Rf / norms[:, None]).astype(R.dtype)
    S = jnp.einsum("im,jm->ij", Rn, Rn, preferred_element_type=jnp.float32)
    S = _constrain(S, block_spec)
    S = _constrain(S, rows_spec)
    idx = jnp.argsort(S, axis=-1).astype(jnp.int32)
    vals = jnp.take_along_axis(S, idx, axis=-1)
    return vals, idx


def onboard_step(state: CFState, R_new: jax.Array, probes: jax.Array,
                 cfg: CFConfig, unroll: bool = False, rows_spec=None,
                 mesh_info=None):
    """TwinSearch burst over the immutable base state (write-buffer
    formulation); with ``mesh_info=(axes, mesh)`` the shard_map
    distributed path runs (core.twinsearch_sharded) — the GSPMD gather
    formulation cannot partition the dynamic row lookups."""
    n_base = state.capacity
    s_max = set0_cap(n_base, cfg.set0_divisor, cfg.set0_slack)
    if mesh_info is not None:
        from repro.core.twinsearch_sharded import onboard_batch_sharded
        axes, mesh = mesh_info
        return onboard_batch_sharded(state, R_new, probes, s_max=s_max,
                                     axes=axes, mesh=mesh,
                                     tol=cfg.sim_tol, unroll=unroll)
    return ts.onboard_batch_buffered(state, R_new, probes, s_max=s_max,
                                     tol=cfg.sim_tol, unroll=unroll,
                                     rows_spec=rows_spec)


def onboard_traditional_step(state: CFState, R_new: jax.Array):
    """The baseline burst (every user through compute-all + sort)."""
    from repro.core import baseline
    state2 = baseline.onboard_batch_traditional(state, R_new)
    k = R_new.shape[0]
    rows = (state.capacity - k) + jnp.arange(k, dtype=jnp.int32)
    return state2.sim_vals[rows], state2.sim_idx[rows]


def state_structs(n_base: int, m: int, k: int,
                  ratings_dtype=jnp.bfloat16) -> CFState:
    """ShapeDtypeStruct stand-in CFState with capacity n_base + k."""
    N = n_base + k
    return CFState(
        ratings=jax.ShapeDtypeStruct((N, m), ratings_dtype),
        norms=jax.ShapeDtypeStruct((N,), jnp.float32),
        sim_vals=jax.ShapeDtypeStruct((N, N), jnp.float32),
        sim_idx=jax.ShapeDtypeStruct((N, N), jnp.int32),
        n_active=jax.ShapeDtypeStruct((), jnp.int32),
    )


def input_structs(cfg: CFConfig, shape: ShapeSpec) -> dict[str, Any]:
    from repro.configs.base import pad_to_shard
    n, m = shape.dim("n_users"), shape.dim("n_items")
    if cfg.mode == "item":
        n, m = m, n
    if shape.kind == "build":
        # Row count pads to the shard boundary (zero rows sort harmlessly;
        # benches at exact scale run unsharded).
        return {"R": jax.ShapeDtypeStruct((pad_to_shard(n), m),
                                          jnp.bfloat16)}
    if shape.kind == "onboard":
        k = shape.dim("k_new")
        n_base = pad_to_shard(n)
        return {
            "state": state_structs(n_base, m, 0),
            "R_new": jax.ShapeDtypeStruct((k, m), jnp.bfloat16),
            "probes": jax.ShapeDtypeStruct((k, cfg.c_probes), jnp.int32),
        }
    raise ValueError(f"unknown CF shape kind {shape.kind}")
