"""Durability-layer throughput: what crash recovery and replica loss cost.

Five questions, answered in wall time:

  * **wal**: append cost per mutating op, with and without fsync — the
    per-request durability tax;
  * **group_commit**: one fsync per batch vs one per record on
    ``onboard_batch`` — how much of the fsync tax coalescing recovers;
  * **replay**: WAL replay time per logged onboard on restart, serial
    (``replay_batch=1``) vs batched (``replay_batch=16``) — how long a
    crash actually costs, vs the traditional rebuild it replaces;
  * **rereplicate**: background re-replication throughput (rows/s of pure
    host-side copy) — how fast r-way redundancy comes back after a node
    loss;
  * **repair**: healing poisoned primary rows from replicas (failover
    read + scatter back) — the cost of NOT rolling back.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import CSV
from repro.distributed.replication import ReplicatedArena, ReplicationConfig
from repro.serving import CFServer, ServerConfig, SnapshotConfig, WalConfig
from repro.testing import poison_state


def _ratings(rng, n, m, density=0.3):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R


def _median(fn, repeats=5):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


_NO_SNAP = SnapshotConfig(every=10**9, check_every=10**9)


def main(csv: CSV) -> None:
    rng = np.random.default_rng(0)
    n, m, extra = 1000, 100, 64
    n_ops = 256
    R = _ratings(rng, n, m)

    # -- WAL append cost, fsync on/off -----------------------------------
    for fsync in (True, False):
        d = tempfile.mkdtemp(prefix="walbench-")
        try:
            srv = CFServer(R, ServerConfig(
                capacity_extra=extra, c_probes=8, snapshot=_NO_SNAP,
                wal=WalConfig(dir=d, fsync=fsync)))
            row = R[rng.integers(0, n)]
            srv.onboard_user(row)                     # compile
            t = _median(lambda: srv.onboard_user(row), repeats=10)
            csv.add(f"wal/onboard_fsync_{int(fsync)}", t,
                    f"m={m} incl. onboard")
            t = _median(lambda: srv.add_rating(5, 3, 4.0), repeats=10)
            csv.add(f"wal/add_rating_fsync_{int(fsync)}", t, "")
        finally:
            shutil.rmtree(d, ignore_errors=True)

    # -- group commit: fsyncs per onboard_batch --------------------------
    batch = np.stack([R[rng.integers(0, n)] for _ in range(8)])
    for gc in (True, False):
        d = tempfile.mkdtemp(prefix="walbench-")
        try:
            srv = CFServer(R, ServerConfig(
                capacity_extra=extra, c_probes=8, snapshot=_NO_SNAP,
                wal=WalConfig(dir=d, group_commit=gc)))
            srv.onboard_user(batch[0])                # compile
            s0, repeats = srv.wal.syncs, 3
            t = _median(lambda: srv.onboard_batch(batch), repeats=repeats)
            syncs = (srv.wal.syncs - s0) // repeats
            csv.add(f"wal/batch8_group_commit_{int(gc)}", t,
                    f"{syncs} fsyncs per 8-row batch")
        finally:
            shutil.rmtree(d, ignore_errors=True)

    # -- replay throughput on recovery: serial vs batched ----------------
    wal_d = tempfile.mkdtemp(prefix="walbench-")
    snap_d = tempfile.mkdtemp(prefix="snapbench-")
    try:
        # buffer sized past n_ops: keep the log free of rotate records so
        # the serial-vs-batched comparison is pure onboard replay
        base = dict(capacity_extra=n_ops + 8, c_probes=8,
                    snapshot=SnapshotConfig(every=10**9, check_every=10**9,
                                            dir=snap_d))
        srv = CFServer(R, ServerConfig(wal=WalConfig(dir=wal_d), **base))
        for _ in range(n_ops):
            srv.onboard_user(R[rng.integers(0, n)])
        t_serial = None
        for b in (1, 16):
            # recovery snapshots + truncates on success; replay each
            # variant from its own copy of the crashed dirs
            w = shutil.copytree(wal_d, tempfile.mkdtemp() + "/wal")
            s = shutil.copytree(snap_d, tempfile.mkdtemp() + "/snap")
            cfg = ServerConfig(
                capacity_extra=n_ops + 8, c_probes=8,
                snapshot=SnapshotConfig(every=10**9, check_every=10**9,
                                        dir=s),
                wal=WalConfig(dir=w, replay_batch=b))
            t0 = time.perf_counter()
            rec = CFServer.recover(R, cfg)
            dt = time.perf_counter() - t0
            assert rec.stats.wal_replayed == n_ops
            note = f"{n_ops} ops, total {dt * 1e3:.0f}ms incl. restore"
            if b == 1:
                t_serial = dt
            else:
                note += f", serial/batched={t_serial / dt:.2f}x"
            csv.add(f"replay/per_onboard_batch{b}", dt / n_ops, note)
            shutil.rmtree(w, ignore_errors=True)
            shutil.rmtree(s, ignore_errors=True)
    finally:
        shutil.rmtree(wal_d, ignore_errors=True)
        shutil.rmtree(snap_d, ignore_errors=True)

    # -- re-replication throughput (pure data movement) ------------------
    srv = CFServer(R, ServerConfig(
        capacity_extra=extra, c_probes=8, snapshot=_NO_SNAP,
        replication=ReplicationConfig(n_shards=8, r=2)))
    reps: ReplicatedArena = srv.replicas
    rows_per_kill = 2 * ((n + extra) // 8)            # 2 replicas per node

    def rebuild():
        reps.kill_node(3)
        return reps.step_rebuild()

    t = _median(rebuild, repeats=5)
    csv.add("rereplicate/full_node", t,
            f"{rows_per_kill} rows, {rows_per_kill / max(t, 1e-9):,.0f} "
            f"rows/s")

    # -- primary repair from replicas (failover read path) ---------------
    bad = None

    def repair():
        nonlocal bad
        bad = poison_state(srv, shard=5, n_shards=8)
        fixed, rows = reps.repair(srv.state)
        assert fixed is not None and rows.size == bad.size
        srv.state = fixed

    t = _median(repair, repeats=5)
    csv.add("repair/shard_rows", t,
            f"{len(bad)} rows healed, zero similarity math")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
