"""Durability-layer throughput: what crash recovery and replica loss cost.

Four questions, answered in wall time:

  * **wal**: append cost per mutating op, with and without fsync — the
    per-request durability tax;
  * **replay**: WAL replay time per logged onboard on restart — how long
    a crash actually costs, vs the traditional rebuild it replaces;
  * **rereplicate**: background re-replication throughput (rows/s of pure
    host-side copy) — how fast r-way redundancy comes back after a node
    loss;
  * **repair**: healing poisoned primary rows from replicas (failover
    read + scatter back) — the cost of NOT rolling back.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import CSV
from repro.distributed.replication import ReplicatedArena, ReplicationConfig
from repro.serving import CFServer
from repro.testing import poison_state


def _ratings(rng, n, m, density=0.3):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R


def _median(fn, repeats=5):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def main(csv: CSV) -> None:
    rng = np.random.default_rng(0)
    n, m, extra = 1000, 100, 64
    n_ops = 32
    R = _ratings(rng, n, m)

    # -- WAL append cost, fsync on/off -----------------------------------
    for fsync in (True, False):
        d = tempfile.mkdtemp(prefix="walbench-")
        try:
            srv = CFServer(R, capacity_extra=extra, c_probes=8,
                           wal_dir=d, wal_fsync=fsync,
                           snapshot_every=10**9, check_every=10**9)
            row = R[rng.integers(0, n)]
            srv.onboard_user(row)                     # compile
            t = _median(lambda: srv.onboard_user(row), repeats=10)
            csv.add(f"wal/onboard_fsync_{int(fsync)}", t,
                    f"m={m} incl. onboard")
            t = _median(lambda: srv.add_rating(5, 3, 4.0), repeats=10)
            csv.add(f"wal/add_rating_fsync_{int(fsync)}", t, "")
        finally:
            shutil.rmtree(d, ignore_errors=True)

    # -- replay throughput on recovery -----------------------------------
    wal_d = tempfile.mkdtemp(prefix="walbench-")
    snap_d = tempfile.mkdtemp(prefix="snapbench-")
    try:
        srv = CFServer(R, capacity_extra=extra, c_probes=8, wal_dir=wal_d,
                       snapshot_dir=snap_d, snapshot_every=10**9,
                       check_every=10**9)
        for _ in range(n_ops):
            srv.onboard_user(R[rng.integers(0, n)])
        t0 = time.perf_counter()
        rec = CFServer.recover(R, capacity_extra=extra, c_probes=8,
                               wal_dir=wal_d, snapshot_dir=snap_d,
                               snapshot_every=10**9, check_every=10**9)
        dt = time.perf_counter() - t0
        assert rec.stats.wal_replayed == n_ops
        csv.add("replay/per_onboard", dt / n_ops,
                f"{n_ops} ops, total {dt * 1e3:.0f}ms incl. restore")
    finally:
        shutil.rmtree(wal_d, ignore_errors=True)
        shutil.rmtree(snap_d, ignore_errors=True)

    # -- re-replication throughput (pure data movement) ------------------
    srv = CFServer(R, capacity_extra=extra, c_probes=8,
                   snapshot_every=10**9, check_every=10**9,
                   replication=ReplicationConfig(n_shards=8, r=2))
    reps: ReplicatedArena = srv.replicas
    rows_per_kill = 2 * ((n + extra) // 8)            # 2 replicas per node

    def rebuild():
        reps.kill_node(3)
        return reps.step_rebuild()

    t = _median(rebuild, repeats=5)
    csv.add("rereplicate/full_node", t,
            f"{rows_per_kill} rows, {rows_per_kill / max(t, 1e-9):,.0f} "
            f"rows/s")

    # -- primary repair from replicas (failover read path) ---------------
    bad = None

    def repair():
        nonlocal bad
        bad = poison_state(srv, shard=5, n_shards=8)
        fixed, rows = reps.repair(srv.state)
        assert fixed is not None and rows.size == bad.size
        srv.state = fixed

    t = _median(repair, repeats=5)
    csv.add("repair/shard_rows", t,
            f"{len(bad)} rows healed, zero similarity math")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
