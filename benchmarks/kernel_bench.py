"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle vs the fused
XLA path, at the paper's hot-spot shapes.  On this CPU container the Pallas
timings exercise interpret mode (correctness path) — the recorded numbers
for real-TPU projection come from the dry-run roofline, not wall clock; the
jnp-vs-jnp rows (similarity build, probe+verify fused vs unfused) are
meaningful relative measurements.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.similarity import cosine_vs_all, row_norms
from repro.kernels.similarity.ref import similarity_ref
from benchmarks.common import CSV, time_call


def main(csv: CSV | None = None) -> None:
    csv = csv or CSV()
    rng = np.random.default_rng(0)
    # MovieLens-scale traditional path: 30 new users vs all 943
    Q = jnp.asarray(rng.normal(size=(30, 1682)).astype(np.float32))
    R = jnp.asarray(rng.normal(size=(943, 1682)).astype(np.float32))
    qn, rn = jnp.linalg.norm(Q, axis=1), jnp.linalg.norm(R, axis=1)

    ref = jax.jit(similarity_ref)
    t = time_call(ref, Q, R, qn, rn)
    csv.add("kernel_similarity_ml_jnp", t, "30x943x1682")

    # Douban-sub scale matvec (one user, the per-user traditional cost)
    R2 = jnp.asarray(rng.normal(size=(8093, 3658)).astype(np.float32))
    n2 = row_norms(R2)
    r0 = R2[5]
    f = jax.jit(cosine_vs_all)
    t = time_call(f, R2, n2, r0)
    csv.add("kernel_cosine_vs_all_douban16", t, "8093x3658")

    # probe+verify (the TwinSearch per-user cost at the same scale)
    from repro.core import build_state, twinsearch_find, set0_cap
    state = jax.jit(lambda R: build_state(R, capacity_extra=1))(R2[:2048])
    probes = jnp.arange(8, dtype=jnp.int32)
    g = jax.jit(lambda s, r, p: twinsearch_find(
        s, r, p, s_max=set0_cap(2048), n_base=2048, k_cap=0).found)
    t = time_call(g, state, R2[5], probes)
    csv.add("kernel_twinsearch_find_2048", t, "c=8")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
