"""Batched query-path throughput at MovieLens scale.

Four rungs of the same read, B users per request batch:

  * ``scalar_loop``   — the pre-PR-10 serving path: one jitted
                        ``knn.recommend`` dispatch per user plus the
                        per-element ``float()``/``int()`` host syncs.
  * ``batched``       — ``knn.recommend_batch`` (vmapped scalar path,
                        row-wise bit-identical), one dispatch + one
                        ``jax.device_get`` for the whole batch.
  * ``batched_kernel``— probe (``top_k_neighbors_batch``) + the fused
                        ``knn_score`` scoring path + on-device top-n.
                        Backend auto-selects: the Pallas kernel on TPU,
                        the einsum on CPU (interpret-mode Pallas would
                        only benchmark the emulator).
  * ``dedup``         — the full ``CFServer.recommend_batch`` endpoint
                        (guards + twin dedup + fan-out) under a
                        twin-fraction sweep: ``twin{f}`` means fraction f
                        of the batch's rows duplicate a small hot set —
                        the query-side analogue of the paper's identical
                        new users.

CSV rows are ``query_{rung}_B{B}[...]`` with median wall microseconds
per *batch*; ``derived`` carries rows/s and the speedup over the scalar
loop at the same B.  Bit-exactness of batched vs scalar is asserted, not
just benchmarked.  ``REPRO_BENCH_FAST=1`` shrinks shapes to a
compile-check (CI smoke) and additionally forces one interpret-mode run
of the Pallas kernel so TPU-targeted code is exercised on every push.
"""
from __future__ import annotations

import os
import warnings

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import CSV, time_call
from repro.core import build_state, knn
from repro.kernels.knn_score.ops import knn_recommend_topn

FAST = os.environ.get("REPRO_BENCH_FAST", "") not in ("", "0")

N_USERS, N_ITEMS = (100, 64) if FAST else (943, 1682)   # MovieLens-100k
BATCHES = (1, 16) if FAST else (1, 16, 256)
TWIN_FRACTIONS = (0.5,) if FAST else (0.0, 0.5, 0.9)
K_NEIGHBORS, N_REC = 20, 10
HOT_SET = 4                      # distinct users the twin rows draw from


def _ratings(rng, n, m, density=0.06):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R


def _scalar_loop(state, users_np, rec_jit):
    """The old serving read path: one dispatch + per-element host sync
    per user."""
    out = []
    for u in users_np:
        scores, items = rec_jit(state, jnp.int32(int(u)))
        out.append([(int(i), float(s)) for s, i in zip(scores, items)])
    return out


def _batched(state, users_dev, batch_jit):
    scores, items = jax.device_get(batch_jit(state, users_dev))
    return [[(int(i), float(s)) for s, i in zip(sr, ir)]
            for sr, ir in zip(scores, items)]


def main(csv: CSV) -> None:
    rng = np.random.default_rng(0)
    R = _ratings(rng, N_USERS, N_ITEMS)
    state = jax.jit(lambda r: build_state(r, capacity_extra=8))(
        jnp.asarray(R))
    state = jax.block_until_ready(state)

    def _probe(st, us):
        sims, nbrs = knn.top_k_neighbors_batch(st, us, K_NEIGHBORS)
        return jnp.maximum(sims, 0.0), nbrs

    rec_jit = jax.jit(lambda st, u: knn.recommend(st, u, K_NEIGHBORS, N_REC))
    batch_jit = jax.jit(lambda st, us: knn.recommend_batch(
        st, us, K_NEIGHBORS, N_REC))
    kernel_jit = jax.jit(lambda st, us: knn_recommend_topn(
        st.ratings, *_probe(st, us), us, N_REC))

    repeats = 1 if FAST else 3
    for B in BATCHES:
        users_np = rng.integers(0, N_USERS, B).astype(np.int32)
        users_dev = jnp.asarray(users_np)

        # bit-exactness gate before any timing
        ref = _scalar_loop(state, users_np, rec_jit)
        got = _batched(state, users_dev, batch_jit)
        if ref != got:
            raise AssertionError(f"batched != scalar at B={B}")

        t_scalar = time_call(lambda s, u=users_np: _scalar_loop(
            s, u, rec_jit), state, warmup=1, repeats=repeats)
        t_batch = time_call(batch_jit, state, users_dev, repeats=repeats)
        t_kernel = time_call(kernel_jit, state, users_dev, repeats=repeats)
        csv.add(f"query_scalar_loop_B{B}", t_scalar,
                f"rows_per_s={B / t_scalar:.0f}")
        csv.add(f"query_batched_B{B}", t_batch,
                f"rows_per_s={B / t_batch:.0f} "
                f"speedup={t_scalar / t_batch:.2f}")
        csv.add(f"query_batched_kernel_B{B}", t_kernel,
                f"rows_per_s={B / t_kernel:.0f} "
                f"speedup={t_scalar / t_kernel:.2f}")

    # full serving endpoint with twin dedup, twin-fraction sweep
    from repro.serving import CFServer, ServerConfig
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        srv = CFServer(R, ServerConfig(capacity_extra=8))
    B = BATCHES[-1]
    hot = rng.integers(0, N_USERS, HOT_SET)
    for f in TWIN_FRACTIONS:
        users = rng.integers(0, N_USERS, B)
        twin_rows = rng.random(B) < f
        users[twin_rows] = hot[rng.integers(0, HOT_SET, int(twin_rows.sum()))]
        srv.recommend_batch(users, n=N_REC, k_neighbors=K_NEIGHBORS)  # warm
        t = time_call(lambda _s, u=users: srv.recommend_batch(
            u, n=N_REC, k_neighbors=K_NEIGHBORS), state, warmup=1,
            repeats=repeats)
        csv.add(f"query_dedup_B{B}_twin{f}", t,
                f"rows_per_s={B / t:.0f} "
                f"savings={srv.stats.query_dedup_savings[-1]:.2f}")

    if FAST:
        # CI compile-check: force the Pallas kernel once in interpret mode
        # so TPU-targeted code paths stay green on every push.
        us = jnp.asarray(rng.integers(0, N_USERS, 4).astype(np.int32))
        w, nbrs = _probe(state, us)
        out = knn_recommend_topn(state.ratings, w, nbrs, us, N_REC,
                                 use_pallas=True, interpret=True)
        jax.block_until_ready(out)
        csv.add("query_kernel_interpret_smoke", 0.0, "compiled=1")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
