"""Roofline report generator: reads the dry-run JSONL and renders the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def load(path: str) -> "OrderedDict[tuple, dict]":
    recs: OrderedDict[tuple, dict] = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return recs


def _ms(x: float) -> str:
    return f"{x * 1e3:.2f}"


def render(recs: dict, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_compute(ms) | t_memory(ms) | t_coll(ms) | "
        "dominant | useful | args/dev | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in recs.items():
        if m != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | "
                         f"— | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
            continue
        ro, me = r["roofline"], r["memory"]
        gb = 1 / (1 << 30)
        lines.append(
            f"| {arch} | {shape} | {_ms(ro['t_compute_s'])} | "
            f"{_ms(ro['t_memory_s'])} | {_ms(ro['t_collective_s'])} | "
            f"{ro['dominant']} | {ro['useful_fraction']:.2f} | "
            f"{me['argument_bytes'] * gb:.2f}GB | "
            f"{me['temp_bytes'] * gb:.2f}GB |")
    return "\n".join(lines)


def summary(recs: dict) -> str:
    n_ok = sum(r["status"] == "ok" for r in recs.values())
    n_skip = sum(r["status"] == "skipped" for r in recs.values())
    n_err = sum(r["status"] == "error" for r in recs.values())
    doms: dict[str, int] = {}
    for r in recs.values():
        if r["status"] == "ok":
            d = r["roofline"]["dominant"]
            doms[d] = doms.get(d, 0) + 1
    return (f"cells ok={n_ok} skipped={n_skip} errors={n_err}; "
            f"dominant-term histogram: {doms}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.inp)
    print(summary(recs))
    print(render(recs, args.mesh))


if __name__ == "__main__":
    main()
