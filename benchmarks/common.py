"""Shared benchmark plumbing: timed jit calls + CSV emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` (jit-compiled, blocked).

    Both the inputs and every returned array are ``block_until_ready``'d:
    ``jax.block_until_ready`` traverses arbitrary pytrees (CFState /
    OnboardStats namedtuples included), so async host-to-device transfers
    of the arguments never leak into the timed region and the timed call
    can't return an unfinished future.
    """
    args = jax.block_until_ready(args)
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


class CSV:
    """Accumulates ``name,us_per_call,derived`` rows for run.py."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, seconds: float, derived: str = "") -> None:
        self.rows.append((name, seconds * 1e6, derived))
        print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)

    def header(self) -> None:
        print("name,us_per_call,derived", flush=True)
