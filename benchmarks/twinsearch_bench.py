"""Paper Figures 2-5: running time of TwinSearch vs the traditional method
for k identical new users, user-based and item-based CF, on MovieLens-scale
and Douban-scale data.

MovieLens runs at the full published scale (943 x 1682).  Douban
(129,490 x 58,541) exceeds this container's single-core time budget for
*timed* runs, so it runs at a 1/32-per-axis subsample with the full-scale
cost reported as ``derived`` via exact cost scaling (the traditional path
is a dense n·m matvec per user; TwinSearch's dominant terms scale with n).
The full-scale Douban cells are also covered FLOP-exactly by the dry-run
rows ``twinsearch-cf/douban_*`` in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import build_state, make_probes, set0_cap
from repro.core.baseline import onboard_batch_traditional
from repro.core.twinsearch import onboard_batch_buffered
from repro.data import douban_film, movielens_100k
from benchmarks.common import CSV, time_call

K_SWEEP = (1, 5, 10, 20, 30)
DOUBAN_SUB = 1 / 32


def _bench_dataset(csv: CSV, name: str, R: np.ndarray, mode: str,
                   scale_note: tuple | None = None) -> None:
    if mode == "item":
        R = R.T.copy()
    n, m = R.shape
    k_max = max(K_SWEEP)
    s_max = set0_cap(n)
    Rj = jnp.asarray(R, jnp.float32)
    state_tw = jax.jit(lambda R: build_state(R, capacity_extra=0))(Rj)
    state_tr = jax.jit(
        lambda R: build_state(R, capacity_extra=k_max))(Rj)
    r0 = R[n // 3].astype(np.float32)

    tw = jax.jit(lambda s, rn, pr: onboard_batch_buffered(
        s, rn, pr, s_max=s_max)[0])
    trad = jax.jit(lambda s, rn: onboard_batch_traditional(
        s, rn).sim_vals[-rn.shape[0]:])   # return rows: defeat DCE
    for k in K_SWEEP:
        R_new = jnp.asarray(np.tile(r0, (k, 1)), jnp.float32)
        probes = make_probes(jax.random.PRNGKey(k), k, 8, n)
        t_tw = time_call(tw, state_tw, R_new, probes)
        t_tr = time_call(trad, state_tr, R_new)
        derived = f"speedup={t_tr / max(t_tw, 1e-9):.1f}x"
        if scale_note is not None:
            full_n, full_m = scale_note
            factor = (full_n / n) * (full_m / m)
            derived += (f";full_scale_traditional_s={t_tr * factor:.1f}"
                        f";full_scale_twinsearch_s="
                        f"{t_tw * (full_n / n):.2f}")
        csv.add(f"fig_{name}_{mode}_k{k}_twinsearch", t_tw, derived)
        csv.add(f"fig_{name}_{mode}_k{k}_traditional", t_tr, "")


def main(csv: CSV | None = None) -> None:
    csv = csv or CSV()
    ml = movielens_100k(seed=0)
    # Fig 2 / Fig 4: MovieLens, user- and item-based (full published scale)
    _bench_dataset(csv, "ml", ml, "user")
    _bench_dataset(csv, "ml", ml, "item")
    # Fig 3 / Fig 5: Douban film at 1/32 subsample per axis
    db = douban_film(seed=0, subsample=DOUBAN_SUB)
    _bench_dataset(csv, "douban", db, "user",
                   scale_note=(129_490, 58_541))
    _bench_dataset(csv, "douban", db, "item",
                   scale_note=(58_541, 129_490))


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
