"""Paper Sec 3.2 complexity model: total onboarding cost vs k should follow
O((1 + (k-1)/125)·m·n) for TwinSearch against O(k·m·n) traditional — i.e.
the TwinSearch curve is nearly flat in k while the traditional curve is
linear.  Sweeps k and n at fixed density and reports the fitted
incremental-cost ratio (paper model: 1/125)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import build_state, make_probes, set0_cap
from repro.core.baseline import onboard_batch_traditional
from repro.core.twinsearch import onboard_batch_buffered
from repro.data import synth_ratings
from benchmarks.common import CSV, time_call


def _pair(R: np.ndarray, k: int, seed: int = 0) -> tuple[float, float]:
    n, m = R.shape
    Rj = jnp.asarray(R, jnp.float32)
    s_max = set0_cap(n)
    st_tw = jax.jit(lambda R: build_state(R, capacity_extra=0))(Rj)
    st_tr = jax.jit(lambda R: build_state(R, capacity_extra=k))(Rj)
    R_new = jnp.asarray(np.tile(R[n // 5].astype(np.float32), (k, 1)))
    probes = make_probes(jax.random.PRNGKey(seed), k, 8, n)
    tw = jax.jit(lambda s, rn, pr: onboard_batch_buffered(
        s, rn, pr, s_max=s_max)[0])
    tr = jax.jit(lambda s, rn: onboard_batch_traditional(
        s, rn).sim_vals[-rn.shape[0]:])   # return rows: defeat DCE
    return (time_call(tw, st_tw, R_new, probes),
            time_call(tr, st_tr, R_new))


def main(csv: CSV | None = None) -> None:
    csv = csv or CSV()
    n, m = 2048, 512
    R = synth_ratings(0, n, m, n * 40)

    ks = (1, 4, 8, 16, 32)
    tws, trs = [], []
    for k in ks:
        t_tw, t_tr = _pair(R, k)
        tws.append(t_tw)
        trs.append(t_tr)
        csv.add(f"scaling_k{k}_twinsearch", t_tw,
                f"traditional_us={t_tr*1e6:.0f};"
                f"speedup={t_tr/max(t_tw,1e-12):.1f}x")

    k_arr = np.asarray(ks, float)
    # incremental cost per extra user, each method
    slope_tw = max(np.polyfit(k_arr, tws, 1)[0], 1e-12)
    slope_tr = max(np.polyfit(k_arr, trs, 1)[0], 1e-12)
    csv.add("scaling_incremental_ratio", slope_tw / slope_tr,
            "paper_model=1/125=0.008")

    for n2 in (1024, 4096):
        R2 = synth_ratings(1, n2, m, n2 * 40)
        t_tw, t_tr = _pair(R2, 8, seed=n2)
        csv.add(f"scaling_n{n2}", t_tw,
                f"speedup={t_tr/max(t_tw,1e-12):.1f}x")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
