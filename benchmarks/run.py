"""Benchmark harness — one module per paper table/figure.

  twinsearch_bench   Figures 2-5 (running time, user/item x ML/Douban)
  setsize_bench      Sec 3.2 |Set_0| / Gaussian-bound validation
  scaling_bench      Sec 3.2 complexity model (k and n sweeps)
  kernel_bench       hot-spot micro-benchmarks
  maintenance_bench  burst-batched k-way merge-insert vs k sequential
                     inserts (bit-exactness asserted), k in {1,5,10,20,30}
  resilience_bench   fault-tolerance overhead: request-guard tax, arena
                     rotation vs fresh rebuild, sync-vs-incremental
                     rotation pause, health-check + snapshot
  recovery_bench     durability throughput: WAL append/group-commit cost,
                     serial vs batched replay, re-replication rows/s,
                     replica repair
  query_bench        batched read path: scalar loop vs batched vs fused
                     kernel vs server twin-dedup, twin-fraction sweep
                     (REPRO_BENCH_FAST=1 -> CI compile-check shapes)

Prints ``name,us_per_call,derived`` CSV.  Roofline terms for the full-scale
cells come from ``python -m repro.launch.dryrun --all`` +
``python -m benchmarks.roofline`` (no wall-clock on this CPU container).
"""
from __future__ import annotations

import argparse

from benchmarks.common import CSV


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["twinsearch", "setsize", "scaling",
                                       "kernel", "maintenance",
                                       "resilience", "recovery", "query"],
                    default=None)
    args, _ = ap.parse_known_args()

    csv = CSV()
    csv.header()
    from benchmarks import (kernel_bench, maintenance_bench, query_bench,
                            recovery_bench, resilience_bench, scaling_bench,
                            setsize_bench, twinsearch_bench)
    todo = {
        "setsize": setsize_bench.main,
        "scaling": scaling_bench.main,
        "kernel": kernel_bench.main,
        "maintenance": maintenance_bench.main,
        "resilience": resilience_bench.main,
        "recovery": recovery_bench.main,
        "query": query_bench.main,
        "twinsearch": twinsearch_bench.main,
    }
    for name, fn in todo.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        fn(csv)


if __name__ == "__main__":
    main()
