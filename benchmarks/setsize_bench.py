"""Paper Sec 3.2 validation: |Set_0| against the n/125 Gaussian bound.

Measures (a) the similarity-value distribution of real synthetic-MovieLens
lists (are they Gaussian-ish in [0,1] as Wei et al. claim?), (b) the
largest sub-list mass vs Eq. 3 with consistent parameters, and (c) the
empirical |Set_0| for c = 1..8 probes — the quantity the static candidate
cap (n/125 x slack) must dominate for the compiled TwinSearch to avoid its
fallback.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.gaussian import (empirical_max_sublist, empirical_set0,
                                 exact_fraction, paper_fraction)
from repro.core.similarity import cosine_matrix
from repro.data import movielens_100k
from benchmarks.common import CSV


def main(csv: CSV | None = None) -> None:
    csv = csv or CSV()
    R = movielens_100k(seed=0)
    n = R.shape[0]
    S = np.asarray(cosine_matrix(jnp.asarray(R, jnp.float32)))

    # (a) distribution moments of one user's list
    row = S[42]
    mu, sigma = float(row.mean()), float(row.std())
    csv.add("setsize_sim_mu", mu, f"sigma={sigma:.4f}")

    # (b) largest sub-list vs bounds
    emp = empirical_max_sublist(row, x=100)
    csv.add("setsize_max_sublist_frac", emp / n,
            f"paper_bound={paper_fraction():.5f};"
            f"consistent_gaussian={exact_fraction(mu, sigma):.5f}")

    # (c) |Set_0| vs probe count (averaged over targets)
    rng = np.random.default_rng(0)
    for c in (1, 2, 4, 8):
        sizes = []
        for t in rng.integers(0, n, 20):
            probes = rng.integers(0, n, c)
            sizes.append(empirical_set0(S[probes], S[probes, t], 1e-6))
        csv.add(f"setsize_set0_c{c}", float(np.mean(sizes)) / n,
                f"bound_frac={1 / 125:.5f};max={max(sizes)}")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
