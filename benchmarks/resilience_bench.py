"""Resilience-layer overhead: what fault tolerance costs the hot path.

Four questions, answered in wall time:

  * **guard**: per-request validation cost on ``onboard_user`` /
    ``add_rating`` — the tax every well-formed request pays;
  * **rotation**: arena rotation (scatter-recover + gate + k-way merge,
    zero similarity recompute) vs a fresh ``build_state`` over the same
    active set.  Rotation trades the rebuild's O(n^2 m) similarity
    recompute for O(n L log L) sorts, so its advantage grows with the
    item count m; at the small m benchmarked here the two are close;
  * **pause**: the worst single-onboard stall under a sustained flood,
    synchronous rotation vs incremental (``budget_rows`` slices drained
    on each onboard, atomic swap at the end) — the latency the
    background plan buys back;
  * **health**: the ``arena_healthy`` invariant sweep + an in-memory
    snapshot — the per-``check_every`` cost of poison detection.
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import CSV, time_call
from repro.core import build_state, rotate_arena
from repro.kernels.verify_rows.ops import arena_healthy
from repro.serving import (CFServer, RotationConfig, ServerConfig,
                           SnapshotConfig)
from repro.serving.guard import validate_ratings_vector


def _ratings(rng, n, m, density=0.3):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R


def _median(fn, repeats=5):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


_NO_SNAP = SnapshotConfig(every=10**9, check_every=10**9)


def main(csv: CSV) -> None:
    rng = np.random.default_rng(0)
    n, m, extra = 2000, 200, 64
    R = _ratings(rng, n, m)

    # -- guard: validation cost per request (pure host-side numpy) -------
    r = R[7]
    t = _median(lambda: validate_ratings_vector(
        r, n_items=m, rating_range=(1.0, 5.0)), repeats=50)
    csv.add("guard/validate_vector", t, f"m={m}")

    srv = CFServer(R, ServerConfig(capacity_extra=extra, c_probes=8))
    t = _median(lambda: srv.add_rating(5, 3, 4.0), repeats=20)
    csv.add("guard/add_rating_guarded", t, "incl. cache update")

    # -- rotation vs fresh build over the same active set ----------------
    for k in (16, 64):
        srv = CFServer(R, ServerConfig(capacity_extra=k, c_probes=8,
                                       snapshot=_NO_SNAP))
        for i in range(k):
            srv.onboard_user(R[rng.integers(0, n)])
        st = srv.state
        n_act = int(st.n_active)
        t_rot = time_call(
            lambda s: rotate_arena(s, n_base=n, extra=extra), st)
        csv.add(f"rotation/rotate_k{k}", t_rot, f"n_act={n_act}")
        active = np.asarray(st.ratings[:n_act])
        t_fresh = time_call(
            lambda a: build_state(jnp.asarray(a), capacity_extra=extra),
            active)
        csv.add(f"rotation/fresh_build_k{k}", t_fresh,
                f"fresh/rotate={t_fresh / t_rot:.2f}x")

    # -- worst onboard stall under flood: sync vs incremental rotation ---
    k = 16
    flood = [R[rng.integers(0, n)] for _ in range(3 * k + 2)]
    pause_sync = None
    for name, rot in (("sync", RotationConfig()),
                      ("incremental", RotationConfig(budget_rows=256,
                                                     reserve_slots=12))):
        fs = CFServer(R, ServerConfig(capacity_extra=k, c_probes=8,
                                      snapshot=_NO_SNAP, rotation=rot))
        for row in flood:
            fs.onboard_user(row)
        s = fs.stats.summary()
        assert s["rotations"] >= 2, name
        pause = s["rotation_pause_max_ms"] / 1e3
        note = (f"{s['rotations']} rotations over {len(flood)} onboards, "
                f"forced_drains={s['forced_drains']}")
        if name == "sync":
            pause_sync = pause
        else:
            note += f", sync/incremental={pause_sync / pause:.2f}x"
        csv.add(f"rotation/pause_{name}", pause, note)

    # -- health check + snapshot cadence cost ----------------------------
    st = srv.state
    t = time_call(lambda s: arena_healthy(s.sim_vals, s.ratings, s.norms,
                                          s.n_active), st)
    csv.add("health/arena_healthy", t, f"cap={st.capacity}")
    t = _median(srv._take_snapshot, repeats=5)
    csv.add("health/snapshot_mem", t, "in-memory tuple")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
