"""Burst-batched sorted-list maintenance at MovieLens scale.

The traditional flow inserts each onboarded user into every stored list one
at a time: k sequential shift-gather passes over the (N, L) arena, k * O(N^2)
work and k kernel launches.  The batched path merges all k (value, index)
pairs per row in ONE fused pass — O(N * (N + k)) — and must produce
bit-identical arenas (asserted below, not just benchmarked).

CSV columns (see benchmarks/run.py): ``name`` is
``maintenance_{seq|batched}_k{k}``, ``us_per_call`` the median wall
microseconds of one jit-compiled, block-until-ready call, and ``derived``
carries ``speedup=<seq/batched>`` on the batched rows (plus the
``traditional_{scan|fused}_k{k}`` build-phase rows with the same layout).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import CSV, time_call
from repro.core import build_state, insert_batch_into_lists, insert_into_lists
from repro.core import baseline

N_USERS, N_ITEMS = 943, 1682            # MovieLens-100k
K_SWEEP = (1, 5, 10, 20, 30)


def _ratings(rng, n, m, density=0.06):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R


def _seq_insert(state, new_users, sims_block):
    """k sequential ``insert_into_lists`` calls under one jit, with the
    per-step ``n_active`` the interleaved flow would see (so the gates —
    and therefore the output — match the batched call exactly)."""
    def step(st, inp):
        u, sims = inp
        st = insert_into_lists(st._replace(n_active=u + 1), u, sims)
        return st, None
    out, _ = jax.lax.scan(step, state, (new_users, sims_block))
    return out._replace(n_active=state.n_active)


def main(csv: CSV) -> None:
    rng = np.random.default_rng(0)
    k_max = max(K_SWEEP)
    R = _ratings(rng, N_USERS, N_ITEMS)
    R_new = _ratings(rng, k_max, N_ITEMS)
    state = build_state(jnp.asarray(R), capacity_extra=k_max)
    for t in range(k_max):
        vals, idx, _ = baseline.build_list(state, jnp.asarray(R_new[t]))
        state = baseline.append_user(state, jnp.asarray(R_new[t]), vals, idx)
    sims_full = jnp.asarray(np.stack([
        np.asarray(baseline.build_list(
            state._replace(n_active=jnp.int32(N_USERS + t)),
            jnp.asarray(R_new[t]))[2]) for t in range(k_max)]))

    seq = jax.jit(_seq_insert)
    bat = jax.jit(lambda st, u, s: insert_batch_into_lists(st, u, s))
    for k in K_SWEEP:
        users = N_USERS + jnp.arange(k, dtype=jnp.int32)
        sims = sims_full[:k]
        a = seq(state, users, sims)
        b = bat(state, users, sims)
        if not (np.array_equal(np.asarray(a.sim_vals), np.asarray(b.sim_vals))
                and np.array_equal(np.asarray(a.sim_idx),
                                   np.asarray(b.sim_idx))):
            raise AssertionError(f"batched insert not bit-exact at k={k}")
        t_seq = time_call(seq, state, users, sims)
        t_bat = time_call(bat, state, users, sims)
        csv.add(f"maintenance_seq_k{k}", t_seq)
        csv.add(f"maintenance_batched_k{k}", t_bat,
                f"speedup={t_seq / t_bat:.2f}")

    # traditional build phase: per-user scan vs one fused (k, m) matmul
    base = build_state(jnp.asarray(R), capacity_extra=k_max)
    for k in (5, 30):
        rows = jnp.asarray(R_new[:k])
        scan_fn = jax.jit(lambda st, rn: baseline.onboard_batch_traditional(
            st, rn, fused=False))
        fused_fn = jax.jit(lambda st, rn: baseline.onboard_batch_traditional(
            st, rn, fused=True))
        t_scan = time_call(scan_fn, base, rows)
        t_fused = time_call(fused_fn, base, rows)
        csv.add(f"traditional_scan_k{k}", t_scan)
        # on CPU the fused path pays Pallas interpret-mode emulation for
        # its one (k, m) x (m, N) kernel call; the ratio is only
        # hardware-meaningful with interpret=False on a TPU
        csv.add(f"traditional_fused_k{k}", t_fused,
                f"speedup={t_scan / t_fused:.2f} (interpret-mode)")


if __name__ == "__main__":
    c = CSV()
    c.header()
    main(c)
