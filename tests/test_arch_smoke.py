"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finiteness.  One test
per assigned architecture (the full configs run via the dry-run only)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as lm_mod
from tests.conftest import reduced_spec

LM_ARCHS = ["olmoe-1b-7b", "llama4-scout-17b-a16e", "gemma3-1b",
            "granite-20b", "gemma-7b"]
REC_ARCHS = ["bst", "xdeepfm", "autoint", "two-tower-retrieval"]


def _finite(x) -> bool:
    return bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    spec = reduced_spec(arch)
    cfg = spec.config
    key = jax.random.PRNGKey(0)
    params = lm_mod.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)

    loss, grads = jax.value_and_grad(
        lambda p: lm_mod.lm_loss(p, toks, cfg, loss_chunk=16))(params)
    assert _finite(loss), arch
    assert all(_finite(g) for g in jax.tree.leaves(grads)), arch

    logits, cache = jax.jit(lambda p, t: lm_mod.prefill(p, t, cfg))(
        params, toks)
    assert logits.shape == (2, cfg.vocab_size)
    lg, cache = jax.jit(
        lambda p, c, t, pos: lm_mod.decode_step(p, c, t, pos, cfg))(
        params, cache, toks[:, -1:], jnp.int32(32))
    assert lg.shape == (2, cfg.vocab_size) and _finite(lg)


def test_gat_cora_smoke(rng):
    spec = reduced_spec("gat-cora")
    cfg = spec.config
    key = jax.random.PRNGKey(0)
    from repro.data import cora_like, molecule_batch
    data = cora_like(0)
    params = gnn_mod.init_params(key, cfg, d_feat=data["feats"].shape[1])
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    loss, grads = jax.value_and_grad(
        lambda p: gnn_mod.loss_full(p, batch, cfg))(params)
    assert _finite(loss)
    assert all(_finite(g) for g in jax.tree.leaves(grads))

    # sampled + molecule regimes
    N = 60
    p2 = gnn_mod.init_params(key, cfg, d_feat=16, n_out=5)
    sb = {"feats": jax.random.normal(key, (N, 16)),
          "roots": jnp.arange(8, dtype=jnp.int32),
          "nbr1": jax.random.randint(key, (8, 4), 0, N),
          "nbr2": jax.random.randint(key, (8 * 5, 3), 0, N),
          "labels": jnp.zeros(8, jnp.int32)}
    assert _finite(gnn_mod.loss_sampled(p2, sb, cfg))
    mol = molecule_batch(0, batch=8, n_nodes=10, n_edges=14, d_feat=16)
    p3 = gnn_mod.init_params(key, cfg, d_feat=16, n_out=2)
    assert _finite(gnn_mod.loss_batched(
        p3, {k: jnp.asarray(v) for k, v in mol.items()}, cfg))


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_smoke(arch, rng):
    spec = reduced_spec(arch)
    cfg = spec.config
    key = jax.random.PRNGKey(0)
    params = rec_mod.init_params(key, cfg)

    if cfg.variant == "two_tower":
        from repro.data import TwoTowerStream
        batch = {k: jnp.asarray(v)
                 for k, v in TwoTowerStream(cfg, 16)(0).items()}
    else:
        from repro.data import CTRStream
        batch = {k: jnp.asarray(v) for k, v in CTRStream(cfg, 16)(0).items()}

    loss, grads = jax.value_and_grad(
        lambda p: rec_mod.loss(p, batch, cfg))(params)
    assert _finite(loss), arch
    assert all(_finite(g) for g in jax.tree.leaves(grads)), arch

    # serve path
    if cfg.variant == "two_tower":
        scores = rec_mod.forward(params, batch, cfg)
        assert scores.shape == (16,) and _finite(scores)
        rs = ShapeSpec("retrieval_cand", "retrieval",
                       {"batch": 1, "n_candidates": 256})
        structs = rec_mod.input_structs(cfg, rs)
        rb = {k: jnp.zeros(v.shape, v.dtype) for k, v in structs.items()}
        s, ids = rec_mod.retrieve(params, rb, cfg, top_k=10)
        assert s.shape == (1, 10)
    else:
        logits = rec_mod.forward(params, batch, cfg)
        assert logits.shape == (16,) and _finite(logits)


def test_twinsearch_cf_smoke(rng):
    from repro.models import cf as cf_mod
    from repro.configs import get_arch
    from repro.core import build_state, make_probes
    spec = get_arch("twinsearch-cf")
    from tests.conftest import make_ratings
    R = make_ratings(rng, n=80, m=30)
    vals, idx = jax.jit(cf_mod.build_step)(jnp.asarray(R, jnp.bfloat16))
    assert vals.shape == (80, 80)
    assert bool(jnp.all(jnp.diff(vals, axis=1) >= -1e-6))

    k = 4
    # the buffered/sharded onboard reads an immutable base state (no
    # preallocated burst slots); lists cover base + burst entries
    state = build_state(jnp.asarray(R), capacity_extra=0)
    R_new = jnp.asarray(np.tile(R[5], (k, 1)), jnp.float32)
    probes = make_probes(jax.random.PRNGKey(0), k, spec.config.c_probes, 80)
    nvals, nidx, stats = cf_mod.onboard_step(state, R_new, probes,
                                             spec.config)
    assert nvals.shape == (k, 80 + k)
    assert bool(np.asarray(stats.found)[1:].all())
