"""Data pipeline: determinism, published-scale properties, samplers."""
from __future__ import annotations

import numpy as np

from repro.configs import get_arch
from repro.data import (CSR, CTRStream, NeighborSampler, TokenPipeline,
                        TwoTowerStream, cora_like, molecule_batch,
                        movielens_100k, plant_twins, random_graph,
                        synth_ratings)
from tests.conftest import tiny_recsys


def test_movielens_shape_and_floor():
    R = movielens_100k(seed=0)
    assert R.shape == (943, 1682)
    n_ratings = int((R != 0).sum())
    assert 90_000 <= n_ratings <= 110_000
    per_user = (R != 0).sum(axis=1)
    assert per_user.min() >= 20                 # the dataset's guarantee
    assert set(np.unique(R)) <= set(range(6))   # integral 0..5


def test_synth_deterministic():
    a = synth_ratings(3, 100, 50, 2000)
    b = synth_ratings(3, 100, 50, 2000)
    np.testing.assert_array_equal(a, b)
    c = synth_ratings(4, 100, 50, 2000)
    assert not np.array_equal(a, c)


def test_plant_twins():
    R = synth_ratings(0, 50, 30, 600)
    block = plant_twins(R, 5, source_user=7)
    assert block.shape == (5, 30)
    assert (block == R[7]).all()
    fresh = plant_twins(R, 3, source_user=None, seed=1)
    assert (fresh == fresh[0]).all()
    assert (fresh[0] != 0).sum() >= 8           # kNN-attack floor


def test_token_pipeline_restart_replay():
    pipe = TokenPipeline(vocab=100, batch=4, seq=16, seed=5)
    a = pipe(3)["tokens"]
    pipe2 = TokenPipeline(vocab=100, batch=4, seq=16, seed=5)
    np.testing.assert_array_equal(a, pipe2(3)["tokens"])
    assert not np.array_equal(pipe(0)["tokens"], pipe(1)["tokens"])
    assert a.max() < 100


def test_cora_like():
    d = cora_like(0)
    assert d["feats"].shape == (2708, 1433)
    assert d["edge_src"].shape == d["edge_dst"].shape
    assert int(d["mask"].sum()) == 140
    assert d["labels"].max() == 6


def test_neighbor_sampler():
    src, dst = random_graph(0, 200, 1000)
    csr = CSR(src, dst, 200)
    samp = NeighborSampler(csr, (5, 3), seed=0)
    roots = np.arange(8)
    out = samp(0, roots)
    assert out["nbr1"].shape == (8, 5)
    assert out["nbr2"].shape == (8 * 6, 3)
    # sampled neighbours are real neighbours (or self for isolated nodes)
    for i, r in enumerate(roots):
        nbrs = set(csr.col[csr.indptr[r]:csr.indptr[r + 1]].tolist())
        for x in out["nbr1"][i]:
            assert int(x) in nbrs or int(x) == r
    # determinism per (seed, step)
    out2 = NeighborSampler(csr, (5, 3), seed=0)(0, roots)
    np.testing.assert_array_equal(out["nbr2"], out2["nbr2"])


def test_ctr_stream_bounds():
    cfg = tiny_recsys(get_arch("xdeepfm").config)
    stream = CTRStream(cfg, batch=32, seed=0)
    b = stream(0)
    assert b["sparse_idx"].shape == (32, 39)
    for f, v in enumerate(cfg.field_vocab_sizes):
        assert b["sparse_idx"][:, f].max() < v
    assert set(np.unique(b["label"])) <= {0.0, 1.0}
    np.testing.assert_array_equal(b["sparse_idx"],
                                  CTRStream(cfg, 32, 0)(0)["sparse_idx"])


def test_two_tower_stream_bounds():
    cfg = tiny_recsys(get_arch("two-tower-retrieval").config)
    b = TwoTowerStream(cfg, batch=16, seed=0)(0)
    assert b["user_id"].max() < cfg.user_vocab
    assert b["item_id"].max() < cfg.item_vocab


def test_molecule_batch():
    d = molecule_batch(0, batch=8, n_nodes=10, n_edges=14, d_feat=16)
    assert d["feats"].shape == (8, 10, 16)
    assert d["edge_src"].shape == (8, 24)       # + self loops
    assert d["edge_src"].max() < 10
