"""Training substrate: optimizer, loop, checkpoint/resume, compression,
straggler policy."""
from __future__ import annotations

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.training import (AdamW, SGD, Action, StragglerMonitor,
                            TrainLoopConfig, checkpoint, compress, init_ef,
                            make_train_step, run_loop, warmup_cosine,
                            wire_bytes)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0],
                               atol=1e-2)


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1e-3)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    p2, _ = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 2.0


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


def test_bf16_params_fp32_master():
    opt = AdamW(lr=0.01)
    params = {"w": jnp.zeros(8, jnp.bfloat16)}
    state = opt.init(params)
    assert state.master["w"].dtype == jnp.float32
    p2, s2 = opt.update({"w": jnp.ones(8, jnp.bfloat16)}, state, params)
    assert p2["w"].dtype == jnp.bfloat16


def _toy_problem():
    """Linear regression 'model' with a deterministic stream."""
    W_true = np.random.default_rng(0).normal(size=(4, 3)).astype(np.float32)

    def batches(step):
        rng = np.random.default_rng([7, step])
        x = rng.normal(size=(16, 4)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(x @ W_true)}

    def loss_fn(params, batch):
        pred = batch["x"] @ params["W"]
        return jnp.mean(jnp.square(pred - batch["y"]))

    params = {"W": jnp.zeros((4, 3))}
    return params, loss_fn, batches


def test_loop_learns_and_checkpoints(tmp_path):
    params, loss_fn, batches = _toy_problem()
    opt = AdamW(lr=0.05)
    step = make_train_step(loss_fn, opt)
    cfg = TrainLoopConfig(n_steps=60, ckpt_dir=str(tmp_path), ckpt_every=20)
    p, s, hist = run_loop(step, params, opt.init(params), batches, cfg)
    assert hist[-1] < hist[0] * 0.1
    assert checkpoint.latest_step(str(tmp_path)) == 60


def test_kill_resume_equivalence(tmp_path):
    """Training 60 straight == training 30, 'crashing', resuming to 60."""
    params, loss_fn, batches = _toy_problem()
    opt = AdamW(lr=0.05)
    step = make_train_step(loss_fn, opt)

    cfg_a = TrainLoopConfig(n_steps=60, ckpt_dir=str(tmp_path / "a"),
                            ckpt_every=10)
    pa, _, _ = run_loop(step, params, opt.init(params), batches, cfg_a)

    cfg_b1 = TrainLoopConfig(n_steps=30, ckpt_dir=str(tmp_path / "b"),
                             ckpt_every=10)
    run_loop(step, params, opt.init(params), batches, cfg_b1)
    cfg_b2 = TrainLoopConfig(n_steps=60, ckpt_dir=str(tmp_path / "b"),
                             ckpt_every=10, resume=True)
    pb, _, _ = run_loop(step, params, opt.init(params), batches, cfg_b2)
    np.testing.assert_allclose(np.asarray(pa["W"]), np.asarray(pb["W"]),
                               atol=1e-6)


def test_accum_matches_full_batch():
    params, loss_fn, batches = _toy_problem()
    opt = AdamW(lr=0.05)
    b = batches(0)
    s1 = make_train_step(loss_fn, opt)
    s4 = make_train_step(loss_fn, opt, accum_steps=4)
    ef = init_ef(params)
    p1, *_ = s1(params, opt.init(params), ef, b)
    p4, *_ = s4(params, opt.init(params), ef, b)
    np.testing.assert_allclose(np.asarray(p1["W"]), np.asarray(p4["W"]),
                               atol=1e-5)


def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, tree, keep_last=2)
    assert checkpoint.all_steps(str(tmp_path)) == [4, 5]
    restored, step, _ = checkpoint.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(10.0))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_checkpoint_crash_mid_save(tmp_path):
    """A crash between tmp-dir write and the atomic rename leaves a
    ``step_*.tmp`` dir: discovery must ignore it, restore must serve the
    previous good step, and the next save must sweep it."""
    tree = {"a": jnp.arange(10.0)}
    checkpoint.save(str(tmp_path), 5, tree)
    # simulate the crash: a half-written tmp dir for a newer step
    stale = tmp_path / "step_0000000006.tmp"
    stale.mkdir()
    (stale / "a.npy").write_bytes(b"garbage")
    assert checkpoint.all_steps(str(tmp_path)) == [5]
    assert checkpoint.latest_step(str(tmp_path)) == 5
    restored, step, _ = checkpoint.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
    checkpoint.save(str(tmp_path), 7, tree)     # sweeps the stale tmp
    assert not stale.exists()
    assert checkpoint.all_steps(str(tmp_path)) == [5, 7]


def test_compression_error_feedback():
    params = {"w": jnp.zeros(1000)}
    ef = init_ef(params)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=1000).astype(np.float32))}
    sent, ef = compress(g, ef, keep_frac=0.05)
    nz = int(jnp.sum(sent["w"] != 0))
    assert nz <= 60
    # residual + sent reconstructs the gradient exactly
    np.testing.assert_allclose(np.asarray(sent["w"] + ef.residual["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    # second step replays the residual
    sent2, ef2 = compress({"w": jnp.zeros(1000)}, ef, keep_frac=0.05)
    assert float(jnp.sum(jnp.abs(sent2["w"]))) > 0
    assert wire_bytes(params, 0.05) < 1000 * 4


def test_straggler_monitor():
    t = [0.0]
    mon = StragglerMonitor(window=20, straggler_ratio=2.0,
                           consecutive_to_shrink=2, clock=lambda: t[0])
    for i in range(30):
        mon.step_started()
        t[0] += 0.10                            # simulate 100ms steps
        a = mon.step_finished()
        assert a == Action.CONTINUE
    for i in range(2):
        mon.step_started()
        t[0] += 1.0                             # 10x straggler
        a = mon.step_finished()
    assert a == Action.CHECKPOINT_AND_SHRINK
    st = mon.stats()
    assert st["p50_s"] < st["max_s"]


def test_shrink_mesh_shape():
    from repro.training.elastic import shrink_mesh_shape
    assert shrink_mesh_shape((16, 16)) == (8, 16)
    assert shrink_mesh_shape((2, 16, 16)) == (1, 16, 16)
