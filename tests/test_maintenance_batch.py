"""Burst-batched sorted-list maintenance: the fused k-way merge-insert must
be element-wise identical to k sequential ``insert_into_lists`` calls in
the interleaved append/insert flow, including edge cases (sentinel-head
inserts, full-capacity rows, duplicate similarity values, k=1)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (build_state, baseline, insert_into_lists,
                        insert_batch_into_lists, make_probes,
                        merge_new_users_into_base, set0_cap, splice_twin,
                        splice_twins, twin_sims_block)
from repro.core.twinsearch import onboard_batch_buffered
from tests.conftest import make_ratings


def _seed_insert_np(vals, idx, sims, new_user, live):
    """The seed repo's shift-gather insert, re-derived in numpy: the
    independent sequential oracle every batched path is held to."""
    out_v, out_i = vals.copy(), idx.copy()
    for r in range(vals.shape[0]):
        if not live[r]:
            continue
        s = sims[r]
        p = np.searchsorted(vals[r], s, side="right")
        if p == 0:
            continue                                  # below min: dropped
        out_v[r] = np.concatenate([vals[r, 1:p], [s], vals[r, p:]])
        out_i[r] = np.concatenate([idx[r, 1:p], [new_user], idx[r, p:]])
    return out_v, out_i


def _interleaved_flow(R, R_new):
    """Sequential reference: append each user then insert it into every
    live list, one at a time — returns the final state and sims rows."""
    n, k = R.shape[0], R_new.shape[0]
    st = build_state(jnp.asarray(R), capacity_extra=k)
    sims_rows = []
    for t in range(k):
        vals, idx, sims = baseline.build_list(st, jnp.asarray(R_new[t]))
        st = baseline.append_user(st, jnp.asarray(R_new[t]), vals, idx)
        st = insert_into_lists(st, jnp.int32(n + t), sims)
        sims_rows.append(np.asarray(sims))
    return st, np.stack(sims_rows)


def _batched_flow(R, R_new, **kw):
    """Append the whole burst, then one fused merge-insert."""
    n, k = R.shape[0], R_new.shape[0]
    st = build_state(jnp.asarray(R), capacity_extra=k)
    for t in range(k):
        vals, idx, _ = baseline.build_list(st, jnp.asarray(R_new[t]))
        st = baseline.append_user(st, jnp.asarray(R_new[t]), vals, idx)
    sims_block = []
    # recompute each user's sims against the FINAL ratings (identical
    # values: sims only involve rows that existed at that user's append)
    for t in range(k):
        st_t = st._replace(n_active=jnp.int32(n + t))
        _, _, sims = baseline.build_list(st_t, jnp.asarray(R_new[t]))
        sims_block.append(np.asarray(sims))
    st = insert_batch_into_lists(st, n + jnp.arange(k, dtype=jnp.int32),
                                 jnp.asarray(np.stack(sims_block)), **kw)
    return st


class TestBatchedInsert:
    @pytest.mark.parametrize("k", [1, 4, 7])
    def test_bit_identical_to_sequential(self, rng, k):
        """Mixed burst (twins + fresh) over a state with sentinel slots."""
        R = make_ratings(rng, n=40, m=16)
        R_new = make_ratings(np.random.default_rng(3), n=k, m=16)
        if k > 2:
            R_new[2] = R[10]                        # planted twin
        st_seq, _ = _interleaved_flow(R, R_new)
        st_bat = _batched_flow(R, R_new)
        assert np.array_equal(np.asarray(st_seq.sim_vals),
                              np.asarray(st_bat.sim_vals))
        assert np.array_equal(np.asarray(st_seq.sim_idx),
                              np.asarray(st_bat.sim_idx))

    def test_k1_degenerate_equals_insert_into_lists(self, rng):
        """A one-user burst is exactly the single-user op."""
        R = make_ratings(rng, n=30, m=12)
        n = R.shape[0]
        st = build_state(jnp.asarray(R), capacity_extra=1)
        vals, idx, sims = baseline.build_list(st, jnp.asarray(R[4]))
        st = baseline.append_user(st, jnp.asarray(R[4]), vals, idx)
        a = insert_into_lists(st, jnp.int32(n), sims)
        b = insert_batch_into_lists(st, jnp.asarray([n], jnp.int32),
                                    sims[None, :])
        assert np.array_equal(np.asarray(a.sim_vals), np.asarray(b.sim_vals))
        assert np.array_equal(np.asarray(a.sim_idx), np.asarray(b.sim_idx))

    def test_insert_matches_seed_oracle(self, rng):
        """The rewritten single insert == the seed's shift-gather math,
        including the sentinel-head slot it consumes."""
        R = make_ratings(rng, n=25, m=10)
        n = R.shape[0]
        st = build_state(jnp.asarray(R), capacity_extra=2)
        vals, idx, sims = baseline.build_list(st, jnp.asarray(R[6]))
        st = baseline.append_user(st, jnp.asarray(R[6]), vals, idx)
        got = insert_into_lists(st, jnp.int32(n), sims)
        rows = np.arange(st.capacity)
        live = (rows < int(st.n_active)) & (rows != n)
        want_v, want_i = _seed_insert_np(
            np.asarray(st.sim_vals), np.asarray(st.sim_idx),
            np.asarray(sims), n, live)
        assert np.array_equal(np.asarray(got.sim_vals), want_v)
        assert np.array_equal(np.asarray(got.sim_idx), want_i)

    def test_full_capacity_drops_minimum(self, rng):
        """No sentinel slack: each insert evicts the row's current minimum,
        and a value below the minimum is itself dropped (exact no-op)."""
        R = make_ratings(rng, n=20, m=8)
        st = build_state(jnp.asarray(R), capacity_extra=0)  # zero slack
        sims = np.asarray(
            jnp.take_along_axis(st.sim_vals, jnp.zeros((20, 1), jnp.int32),
                                axis=1))[:, 0]
        # half the rows get a value above their min, half strictly below
        ins = np.where(np.arange(20) % 2 == 0, 0.5, -1.99).astype(np.float32)
        live = np.ones(20, bool)
        want_v, want_i = _seed_insert_np(np.asarray(st.sim_vals),
                                         np.asarray(st.sim_idx),
                                         ins, 20, live)
        # below-min rows must be untouched
        assert np.array_equal(want_v[1], np.asarray(st.sim_vals)[1])
        got = insert_batch_into_lists(
            st._replace(n_active=jnp.int32(20)),
            jnp.asarray([20], jnp.int32), jnp.asarray(ins)[None, :])
        # new_users=20 > every row id: all rows live, matching `live`
        assert np.array_equal(np.asarray(got.sim_vals), want_v)
        assert np.array_equal(np.asarray(got.sim_idx), want_i)
        del sims

    def test_duplicate_values_keep_burst_order(self, rng):
        """Equal sims within the burst and against stored entries: newer
        entries land to the right of older equals (side='right')."""
        R = make_ratings(rng, n=30, m=12)
        n = R.shape[0]
        k = 3
        R_new = np.tile(R[5][None, :], (k, 1))      # identical burst
        st_seq, _ = _interleaved_flow(R, R_new)
        st_bat = _batched_flow(R, R_new)
        assert np.array_equal(np.asarray(st_seq.sim_vals),
                              np.asarray(st_bat.sim_vals))
        assert np.array_equal(np.asarray(st_seq.sim_idx),
                              np.asarray(st_bat.sim_idx))


class TestSpliceTwins:
    def test_vectorised_equals_single_splices(self, rng):
        R = make_ratings(rng, n=35, m=14)
        n = R.shape[0]
        k = 3
        twins = [4, 11, 4]
        R_new = np.stack([R[t] for t in twins])
        st = build_state(jnp.asarray(R), capacity_extra=k)
        for t in range(k):
            vals, idx, _ = baseline.build_list(st, jnp.asarray(R_new[t]))
            st = baseline.append_user(st, jnp.asarray(R_new[t]), vals, idx)
        a = st
        for t in range(k):
            a = splice_twin(a._replace(n_active=jnp.int32(n + t + 1)),
                            jnp.int32(n + t), jnp.int32(twins[t]))
        a = a._replace(n_active=st.n_active)
        b = splice_twins(st, n + jnp.arange(k, dtype=jnp.int32),
                         jnp.asarray(twins, jnp.int32))
        assert np.array_equal(np.asarray(a.sim_vals), np.asarray(b.sim_vals))
        assert np.array_equal(np.asarray(a.sim_idx), np.asarray(b.sim_idx))

    def test_twin_sims_block_gathers_stored_values(self, rng):
        R = make_ratings(rng, n=20, m=10)
        st = build_state(jnp.asarray(R), capacity_extra=0)
        blk = np.asarray(twin_sims_block(st, jnp.asarray([3, 7], jnp.int32)))
        S = np.asarray(st.sim_vals)
        I = np.asarray(st.sim_idx)
        for ti, tw in enumerate((3, 7)):
            for x in (0, 9, 19):
                pos = int(np.argmax(I[x] == tw))
                assert blk[ti, x] == S[x, pos]


class TestBufferedMaintain:
    def test_maintained_base_lists_match_arena_flow(self, rng):
        """onboard_batch_buffered(maintain=True) == the mutable-arena
        interleaved flow on every base row (same sims -> bit-exact)."""
        R = make_ratings(rng, n=48, m=16)
        n = R.shape[0]
        k = 4
        fresh = make_ratings(np.random.default_rng(9), n=1, m=16)[0]
        R_new = np.stack([R[17], fresh, R[17], fresh])
        st_seq, _ = _interleaved_flow(R, R_new)
        base = build_state(jnp.asarray(R), capacity_extra=0)
        probes = make_probes(jax.random.PRNGKey(0), k, 6, n)
        _, _, _, (mv, mi) = onboard_batch_buffered(
            base, jnp.asarray(R_new), probes, s_max=set0_cap(n),
            maintain=True)
        np.testing.assert_allclose(np.asarray(mv),
                                   np.asarray(st_seq.sim_vals[:n]),
                                   atol=2e-5)
        # every base row now lists each new user exactly once
        for u in (0, 23, 47):
            ids = np.asarray(mi[u])
            for t in range(k):
                assert (ids == n + t).sum() == 1

    def test_merge_new_users_consumes_all_sentinel_pads(self, rng):
        R = make_ratings(rng, n=16, m=8)
        st = build_state(jnp.asarray(R), capacity_extra=0)
        k = 3
        sims_block = np.asarray(
            np.random.default_rng(2).uniform(-1, 1, (k, 16)),
            dtype=np.float32)
        mv, mi = merge_new_users_into_base(
            st.sim_vals, st.sim_idx, jnp.asarray(sims_block),
            16 + jnp.arange(k, dtype=jnp.int32))
        assert mv.shape == (16, 16 + k)
        assert not bool(jnp.any(mi == -1))          # pad idx never surfaces
        assert bool(jnp.all(mv[:, 1:] >= mv[:, :-1]))


class TestFusedTraditional:
    def test_fused_matches_sequential_scan(self, rng):
        R = make_ratings(rng, n=40, m=16)
        k = 5
        R_new = make_ratings(np.random.default_rng(4), n=k, m=16)
        R_new[1] = R[7]
        st_a = baseline.onboard_batch_traditional(
            build_state(jnp.asarray(R), capacity_extra=k),
            jnp.asarray(R_new), fused=False)
        st_b = baseline.onboard_batch_traditional(
            build_state(jnp.asarray(R), capacity_extra=k),
            jnp.asarray(R_new), fused=True)
        assert int(st_a.n_active) == int(st_b.n_active)
        assert np.array_equal(np.asarray(st_a.ratings),
                              np.asarray(st_b.ratings))
        np.testing.assert_allclose(np.asarray(st_a.norms),
                                   np.asarray(st_b.norms), atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_a.sim_vals),
                                   np.asarray(st_b.sim_vals), atol=2e-5)
