"""Optional-``hypothesis`` shim: real decorators when the package is
installed, skip stubs otherwise.

The tier-1 container ships without ``hypothesis``; a hard import makes
pytest error at *collection*, taking every non-property test in the module
down with it.  Importing ``given``/``settings``/``st`` from here keeps the
example-based tests running everywhere and surfaces the property tests as
explicit skips (they run in CI, which installs requirements-dev.txt).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Replace the test with an argument-free skip stub (the original
        body references strategy-driven arguments pytest can't supply)."""
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass                       # pragma: no cover
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any strategy constructor call; never executed."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
