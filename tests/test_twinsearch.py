"""Properties and behaviour of the paper's core algorithm."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from tests.hypcompat import given, settings, st

from repro.core import (build_state, make_probes, onboard_batch,
                        onboard_batch_traditional, set0_cap,
                        twinsearch_find)
from repro.core.reference import (build_sorted_lists_np, cosine_vs_all_np,
                                  twinsearch_np)
from tests.conftest import make_ratings


def _state(R, k):
    return build_state(jnp.asarray(R), capacity_extra=k)


class TestTwinFound:
    def test_planted_twin_is_found(self, rng):
        R = make_ratings(rng)
        n = R.shape[0]
        state = _state(R, 1)
        res = twinsearch_find(state, jnp.asarray(R[7]),
                              jnp.arange(6, dtype=jnp.int32),
                              s_max=set0_cap(n), n_base=n, k_cap=0)
        assert bool(res.found)
        # the verified twin's ratings are exactly the probe row
        assert np.array_equal(np.asarray(state.ratings[res.twin_idx]), R[7])

    def test_no_false_twin(self, rng):
        R = make_ratings(rng)
        n = R.shape[0]
        r0 = R[3].copy()
        r0[0] = 1.0 if r0[0] != 1.0 else 2.0            # perturb: no twin
        # ensure uniqueness
        assert not (R == r0).all(axis=1).any()
        state = _state(R, 1)
        res = twinsearch_find(state, jnp.asarray(r0),
                              jnp.arange(6, dtype=jnp.int32),
                              s_max=set0_cap(n), n_base=n, k_cap=0)
        assert not bool(res.found)

    def test_matches_numpy_oracle(self, rng):
        R = make_ratings(rng, n=150, m=50)
        n = R.shape[0]
        sv, si = build_sorted_lists_np(R)
        state = _state(R, 1)
        probes = np.asarray([3, 50, 77, 140])
        for src in (0, 42, 99):
            r0 = R[src]
            found_np, twin_np, set0 = twinsearch_np(R, sv, si, r0, probes)
            res = twinsearch_find(state, jnp.asarray(r0),
                                  jnp.asarray(probes, jnp.int32),
                                  s_max=set0_cap(n), n_base=n, k_cap=0)
            assert bool(res.found) == found_np
            # both twins must verify exactly (indices may differ on ties)
            assert np.array_equal(np.asarray(
                state.ratings[res.twin_idx]), r0)
            assert int(res.n_candidates) == len(set0)

    def test_overflow_flag(self, rng):
        R = make_ratings(rng)
        n = R.shape[0]
        state = _state(R, 1)
        res = twinsearch_find(state, jnp.asarray(R[7]),
                              jnp.arange(4, dtype=jnp.int32), s_max=n,
                              n_base=n, k_cap=0)
        assert not bool(res.overflowed)
        # s_max=0-ish cap forces overflow reporting when candidates exist
        res2 = twinsearch_find(state, jnp.asarray(R[7]),
                               jnp.arange(4, dtype=jnp.int32), s_max=1,
                               n_base=n, k_cap=0)
        assert int(res2.n_candidates) >= 1


class TestOnboardEquivalence:
    """The paper's guarantee: the copied list is the traditional list."""

    @pytest.mark.parametrize("burst", ["twins", "mixed", "all_fresh"])
    def test_burst_matches_traditional(self, rng, burst):
        R = make_ratings(rng, n=100, m=30)
        n = R.shape[0]
        if burst == "twins":
            R_new = np.tile(R[17], (6, 1))
        elif burst == "mixed":
            fresh = make_ratings(rng, n=1, m=30)[0]
            R_new = np.stack([R[17], fresh, R[17], fresh, fresh, R[3]])
        else:
            R_new = make_ratings(np.random.default_rng(9), n=6, m=30)
        k = R_new.shape[0]
        st_tw, stats = onboard_batch(_state(R, k), jnp.asarray(R_new),
                                     make_probes(jax.random.PRNGKey(0), k,
                                                 6, n))
        st_tr = onboard_batch_traditional(_state(R, k), jnp.asarray(R_new))
        for j in range(k):
            v1 = np.asarray(st_tw.sim_vals[n + j])
            v2 = np.asarray(st_tr.sim_vals[n + j])
            np.testing.assert_allclose(v1, v2, atol=2e-5)
            # idx consistency: sorted values must match the sims they index
            idx = np.asarray(st_tw.sim_idx[n + j])
            assert len(np.unique(idx)) == len(idx)

    def test_twin_hits_expected(self, rng):
        """k identical users: user 1 falls back, users 2..k hit."""
        R = make_ratings(rng, n=80, m=25)
        n = R.shape[0]
        fresh = make_ratings(np.random.default_rng(5), n=1, m=25)[0]
        assert not (R == fresh).all(axis=1).any()
        k = 5
        R_new = np.tile(fresh, (k, 1))
        _, stats = onboard_batch(_state(R, k), jnp.asarray(R_new),
                                 make_probes(jax.random.PRNGKey(1), k, 6, n))
        found = np.asarray(stats.found)
        assert not found[0]                  # no twin exists yet
        assert found[1:].all()               # later users twin user n+0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(10, 60),
       st.integers(8, 30), st.integers(2, 8))
def test_property_planted_twin_always_found(seed, n, m, c):
    """For ANY rating matrix and ANY probe set, a planted exact twin is
    found and its copied list equals the traditional build."""
    rng = np.random.default_rng(seed)
    R = make_ratings(rng, n=n, m=m)
    src = int(rng.integers(0, n))
    state = build_state(jnp.asarray(R), capacity_extra=1)
    probes = jnp.asarray(rng.integers(0, n, c), jnp.int32)
    res = twinsearch_find(state, jnp.asarray(R[src]), probes,
                          s_max=max(8, n), n_base=n, k_cap=0)
    assert bool(res.found)
    assert np.array_equal(np.asarray(state.ratings[res.twin_idx]), R[src])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_set0_contains_all_twins(seed):
    """|Set_0| >= number of exact twins (candidate generation is sound)."""
    rng = np.random.default_rng(seed)
    R = make_ratings(rng, n=50, m=15, density=0.5)
    R[10] = R[20]
    R[30] = R[20]                             # 3-way twin group
    state = build_state(jnp.asarray(R), capacity_extra=1)
    probes = jnp.asarray(rng.integers(0, 50, 5), jnp.int32)
    res = twinsearch_find(state, jnp.asarray(R[20]), probes, s_max=50,
                          n_base=50, k_cap=0)
    n_twins = int((R == R[20]).all(axis=1).sum())
    assert int(res.n_candidates) >= n_twins
    assert bool(res.found)
