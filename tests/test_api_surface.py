"""Snapshot of the public serving API.

These tests fail when the exported surface changes *silently* — adding or
removing a name, renaming a config field, or breaking a re-export must be
a deliberate edit here, not an accident of an import shuffle.
"""
import dataclasses

import pytest

import repro.serving as serving


EXPECTED_ALL = {
    # server + results
    "CFServer", "OnboardResult", "ServerStats",
    # configuration
    "ServerConfig", "SnapshotConfig", "WalConfig", "RotationConfig",
    "LadderConfig", "ReplicationConfig",
    # degradation ladder levels
    "LEVEL_TWINSEARCH", "LEVEL_TRADITIONAL", "LEVEL_DEGRADED", "LEVEL_SHED",
    # request guard
    "Quarantine", "Rejection", "RetryPolicy", "call_with_retry",
    # durability
    "WalRecord", "WriteAheadLog",
    # twin-dedup utilities (LM prompts + CF query batches)
    "DedupPlan", "dedup_batch", "dedup_rows", "fan_out", "prompt_hash",
    "LMServer",
}

SERVER_CONFIG_FIELDS = {
    "capacity_extra", "c_probes", "sim_tol", "measure", "seed",
    "rating_range", "quarantine_capacity", "latency_window", "replication",
    "snapshot", "wal", "rotation", "ladder",
}

SUB_CONFIG_FIELDS = {
    "SnapshotConfig": {"every", "dir", "keep", "check_every"},
    "WalConfig": {"dir", "fsync", "group_commit", "replay_batch"},
    "RotationConfig": {"headroom", "budget_rows", "reserve_slots"},
    "LadderConfig": {"recover_after", "shed_cooldown_s", "drain_on_shed",
                     "retry", "monitor"},
}

ONBOARD_RESULT_FIELDS = {
    "user_id", "status", "rung", "latency_ms", "rotated", "seq",
    "twin_found", "reason", "detail", "retry_after_s",
}


class TestServingSurface:
    def test_all_snapshot(self):
        assert set(serving.__all__) == EXPECTED_ALL

    def test_every_export_resolves(self):
        for name in serving.__all__:
            assert getattr(serving, name, None) is not None, name

    def test_server_config_fields(self):
        got = {f.name for f in dataclasses.fields(serving.ServerConfig)}
        assert got == SERVER_CONFIG_FIELDS

    @pytest.mark.parametrize("name", sorted(SUB_CONFIG_FIELDS))
    def test_sub_config_fields(self, name):
        cls = getattr(serving, name)
        got = {f.name for f in dataclasses.fields(cls)}
        assert got == SUB_CONFIG_FIELDS[name]

    def test_onboard_result_fields(self):
        got = {f.name for f in dataclasses.fields(serving.OnboardResult)}
        assert got == ONBOARD_RESULT_FIELDS

    def test_configs_frozen(self):
        cfg = serving.ServerConfig()
        with pytest.raises(dataclasses.FrozenInstanceError):
            cfg.capacity_extra = 1
        with pytest.raises(dataclasses.FrozenInstanceError):
            cfg.wal.fsync = False

    def test_query_endpoints(self):
        for name in ("recommend", "predict", "recommend_batch",
                     "predict_batch"):
            assert callable(getattr(serving.CFServer, name)), name

    def test_server_stats_query_fields(self):
        got = {f.name for f in dataclasses.fields(serving.ServerStats)}
        assert {"queries", "query_batches", "query_unique",
                "query_degraded"} <= got
        summary = serving.ServerStats().summary()
        for key in ("queries", "query_batches", "query_unique",
                    "query_degraded", "query_p50_ms", "query_p99_ms",
                    "query_dedup_savings"):
            assert key in summary, key

    def test_batch_query_exports(self):
        import repro.core as core
        import repro.kernels as kernels
        for name in ("predict_batch", "recommend_batch",
                     "top_k_neighbors_batch"):
            assert callable(getattr(core.knn, name)), name
        for name in ("knn_scores", "knn_recommend_topn"):
            assert callable(getattr(kernels, name)), name

    def test_result_legacy_shapes(self):
        res = serving.OnboardResult(user_id=7, status="ok", twin_found=True,
                                    latency_ms=1.5, rung="twinsearch")
        uid, info = res                      # legacy tuple unpack
        assert uid == 7 and info is res
        assert res[0] == 7 and res[1] is res
        assert res["status"] == "ok"
        assert res["twin_found"] is True
        assert res["ms"] == 1.5              # legacy key -> latency_ms
        assert res["level"] == "twinsearch"  # legacy key -> rung
        assert res.get("retry_after_s", 0.0) == 0.0   # unset -> default
        assert "retry_after_s" not in res
        assert "status" in res
        with pytest.raises(KeyError):
            res["no_such_key"]
        assert res.ok
