"""Similarity measures vs direct NumPy references + invariants."""
from __future__ import annotations

import numpy as np
from tests.hypcompat import given, settings, st

import jax.numpy as jnp

from repro.core import (cosine_matrix, cosine_vs_all, pearson_matrix,
                        adjusted_cosine_matrix, row_norms, sort_rows)
from tests.conftest import make_ratings


def test_cosine_matches_numpy(rng):
    R = make_ratings(rng)
    S = np.asarray(cosine_matrix(jnp.asarray(R)))
    norms = np.linalg.norm(R, axis=1)
    ref = (R / norms[:, None]) @ (R / norms[:, None]).T
    np.testing.assert_allclose(S, ref, atol=1e-5)


def test_cosine_vs_all_consistent_with_matrix(rng):
    R = make_ratings(rng)
    S = np.asarray(cosine_matrix(jnp.asarray(R)))
    sims = np.asarray(cosine_vs_all(jnp.asarray(R),
                                    row_norms(jnp.asarray(R)),
                                    jnp.asarray(R[11])))
    np.testing.assert_allclose(sims, S[11], atol=1e-5)


def test_pearson_exact_co_support(rng):
    """Matmul-form Pearson == per-pair loop over co-rated items."""
    R = make_ratings(rng, n=25, m=18, density=0.5)
    S = np.asarray(pearson_matrix(jnp.asarray(R)))
    for u in range(0, 25, 7):
        for v in range(0, 25, 5):
            co = (R[u] != 0) & (R[v] != 0)
            if co.sum() < 2:
                assert S[u, v] == 0.0
                continue
            a, b = R[u][co].astype(np.float64), R[v][co].astype(np.float64)
            va = ((a - a.mean()) ** 2).sum()
            vb = ((b - b.mean()) ** 2).sum()
            if va < 1e-9 or vb < 1e-9:
                continue                     # degenerate: clamped in impl
            ref = ((a - a.mean()) * (b - b.mean())).sum() / np.sqrt(va * vb)
            np.testing.assert_allclose(S[u, v], ref, atol=1e-4)


def test_adjusted_cosine_centres_by_user(rng):
    R = make_ratings(rng, n=20, m=12, density=0.6)   # items x users layout
    S = np.asarray(adjusted_cosine_matrix(jnp.asarray(R)))
    assert S.shape == (20, 20)
    np.testing.assert_allclose(np.diag(S), 1.0, atol=1e-5)
    np.testing.assert_allclose(S, S.T, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_similarity_invariants(seed):
    rng = np.random.default_rng(seed)
    R = make_ratings(rng, n=30, m=12)
    S = np.asarray(cosine_matrix(jnp.asarray(R)))
    assert np.all(S <= 1.0 + 1e-5) and np.all(S >= -1.0 - 1e-5)
    np.testing.assert_allclose(np.diag(S), 1.0, atol=1e-5)
    np.testing.assert_allclose(S, S.T, atol=1e-6)
    # twins => identical similarity rows (Relationship 1)
    R2 = R.copy()
    R2[4] = R2[9]
    S2 = np.asarray(cosine_matrix(jnp.asarray(R2)))
    np.testing.assert_allclose(S2[4], S2[9], atol=1e-6)


def test_sorted_lists_ascending(rng):
    R = make_ratings(rng)
    S = cosine_matrix(jnp.asarray(R))
    vals, idx = sort_rows(S)
    v = np.asarray(vals)
    assert np.all(np.diff(v, axis=1) >= -1e-7)
    i = np.asarray(idx)
    for row in i[:5]:
        assert len(np.unique(row)) == len(row)
