"""Serving layer: CF server end-to-end, twin-prompt dedup, LM generate."""
from __future__ import annotations

import numpy as np

import jax

from repro.serving import CFServer, LMServer, dedup_batch, fan_out
from tests.conftest import make_ratings, reduced_spec


class TestCFServer:
    def test_onboard_twin_fast_path(self, rng):
        R = make_ratings(rng, n=100, m=30)
        srv = CFServer(R, capacity_extra=8, c_probes=6)
        uid, info = srv.onboard_user(R[11])
        assert uid == 100 and info["twin_found"]
        # identical duplicate users keep hitting
        for _ in range(3):
            _, info = srv.onboard_user(R[11])
            assert info["twin_found"]
        assert srv.stats.twin_hits == 4

    def test_onboard_fresh_falls_back_then_twins(self, rng):
        R = make_ratings(rng, n=80, m=25)
        srv = CFServer(R, capacity_extra=8)
        fresh = make_ratings(np.random.default_rng(42), n=1, m=25)[0]
        _, info1 = srv.onboard_user(fresh)
        assert not info1["twin_found"]
        _, info2 = srv.onboard_user(fresh)
        assert info2["twin_found"]               # twins the first copy
        s = srv.stats.summary()
        assert s["onboarded"] == 2 and s["fallbacks"] == 1

    def test_queries_and_updates(self, rng):
        R = make_ratings(rng, n=60, m=20)
        srv = CFServer(R, capacity_extra=4)
        recs = srv.recommend(3, n=5)
        assert len(recs) == 5
        assert all(R[3, i] == 0 for i, _ in recs)
        p = srv.predict(3, 7)
        assert 0.0 <= p <= 5.0
        srv.add_rating(3, 7, 5.0)
        assert float(srv.state.ratings[3, 7]) == 5.0

    def test_capacity_rotates_instead_of_raising(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=1)
        srv.onboard_user(R[0])                  # arena now full
        uid, info = srv.onboard_user(R[1])      # rotation, not RuntimeError
        assert uid == 21 and info["status"] == "ok"
        assert srv.stats.rotations == 1
        assert srv.n_base == 21 and srv.state.capacity == 22

    def test_double_flood_stays_bit_exact(self, rng):
        """Flood past capacity twice (two+ rotations): every similarity
        value must stay bitwise identical to a never-rotated server that
        onboarded the same sequence — rotation schedules rearrange
        values, they never recompute them.  (The oracle onboards through
        the same twin-search path: twin-copy vs traditional recompute
        differ by ULPs, rotation differs by nothing.)"""
        from repro.core import rotate_arena, unsorted_rows
        import jax.numpy as jnp

        R = make_ratings(rng, n=30, m=12)
        pool = np.concatenate(
            [R[:4], make_ratings(np.random.default_rng(77), n=6, m=12)])
        srv = CFServer(R, capacity_extra=4, c_probes=4)
        oracle = CFServer(R, capacity_extra=64, c_probes=4)  # never rotates
        for i in range(10):                      # 4-slot arena: 2 rotations
            _, a = srv.onboard_user(pool[i % len(pool)])
            _, b = oracle.onboard_user(pool[i % len(pool)])
            assert a["status"] == b["status"] == "ok"
        assert oracle.stats.rotations == 0
        assert srv.stats.rotations >= 2
        n_act = int(srv.state.n_active)
        assert n_act == int(oracle.state.n_active) == 40

        def full_block(s, n_base):
            # materialise deferred symmetric entries, then recover the
            # unsorted (n_act, n_act) all-pairs block
            st = rotate_arena(s.state, n_base=n_base, extra=0)
            rows = unsorted_rows(st.sim_vals, st.sim_idx,
                                 jnp.arange(n_act, dtype=jnp.int32))
            return np.asarray(rows)[:, :n_act]

        np.testing.assert_array_equal(full_block(srv, srv.n_base),
                                      full_block(oracle, oracle.n_base))
        np.testing.assert_array_equal(np.asarray(srv.state.ratings[:n_act]),
                                      np.asarray(
                                          oracle.state.ratings[:n_act]))


class TestDedup:
    def test_dedup_collapses_twins(self):
        rng = np.random.default_rng(0)
        uniq = rng.integers(0, 100, (3, 16)).astype(np.int32)
        batch = uniq[[0, 1, 0, 2, 1, 0]]
        plan = dedup_batch(batch)
        assert plan.n_unique == 3
        assert plan.savings == 0.5
        res = np.arange(3)[:, None] * np.ones((1, 4))
        out = fan_out(res, plan)
        np.testing.assert_array_equal(out[:, 0], [0, 1, 0, 2, 1, 0])

    def test_no_false_sharing(self):
        a = np.zeros((2, 8), np.int32)
        a[1, 7] = 1
        plan = dedup_batch(a)
        assert plan.n_unique == 2


class TestLMServer:
    def test_generate_dedup_consistent(self):
        spec = reduced_spec("gemma3-1b")
        cfg = spec.config
        params = __import__("repro.models.transformer",
                            fromlist=["x"]).init_params(
            jax.random.PRNGKey(0), cfg)
        srv = LMServer(params, cfg, max_len=64)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        batch = prompts[[0, 1, 0, 0]]
        out_dedup, info = srv.generate(batch, n_new=4, dedup=True)
        out_full, _ = srv.generate(batch, n_new=4, dedup=False)
        assert info["prefill_rows"] == 2 and info["dedup_savings"] == 0.5
        np.testing.assert_array_equal(out_dedup, out_full)
        np.testing.assert_array_equal(out_dedup[0], out_dedup[2])
