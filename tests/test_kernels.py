"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import (cosine_similarity, embedding_bag, knn_scores,
                           knn_recommend_topn, merge_insert, twin_probe,
                           verify_rows)
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.knn_score.ref import knn_scores_ref
from repro.kernels.list_merge.ref import merge_insert_ref
from repro.kernels.similarity.ref import similarity_ref
from repro.kernels.twin_probe.ref import twin_probe_ref
from repro.kernels.verify_rows.ref import verify_rows_ref
from tests.hypcompat import given, settings, st


@pytest.mark.parametrize("nq,n,m", [(8, 16, 32), (37, 451, 300),
                                    (128, 256, 512), (1, 943, 1682),
                                    (130, 259, 515)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_similarity_sweep(nq, n, m, dtype):
    rng = np.random.default_rng(nq * 1000 + n)
    Q = jnp.asarray(rng.normal(size=(nq, m)).astype(np.float32)).astype(
        dtype)
    R = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32)).astype(
        dtype)
    out = cosine_similarity(Q, R)
    qn = jnp.linalg.norm(Q.astype(jnp.float32), axis=1)
    rn = jnp.linalg.norm(R.astype(jnp.float32), axis=1)
    ref = similarity_ref(Q, R, qn, rn)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


@pytest.mark.parametrize("c,N", [(2, 64), (8, 700), (16, 2048), (8, 513)])
def test_twin_probe_sweep(c, N):
    rng = np.random.default_rng(c * N)
    rows = jnp.asarray(rng.uniform(0, 1, (c, N)).astype(np.float32))
    s0 = rows[:, N // 3]
    mask, count = twin_probe(rows, s0, tol=1e-6)
    mref, cref = twin_probe_ref(rows, s0, 1e-6)
    assert np.array_equal(np.asarray(mask), np.asarray(mref))
    assert int(count) == int(cref)


@pytest.mark.parametrize("s,m", [(8, 16), (37, 211), (256, 512), (300, 700)])
@pytest.mark.parametrize("dtype", [np.float32, np.int8])
def test_verify_rows_sweep(s, m, dtype):
    rng = np.random.default_rng(s * m)
    C = jnp.asarray(rng.integers(0, 6, (s, m)).astype(dtype))
    r0 = C[s // 2]
    valid = jnp.asarray(rng.random(s) < 0.8)
    out = verify_rows(C, r0, valid)
    ref = verify_rows_ref(C, r0, valid)[:, 0]
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("nb,hot,V,dim", [(4, 2, 50, 8), (16, 8, 1000, 128),
                                          (33, 5, 200, 64)])
def test_embedding_bag_sweep(nb, hot, V, dim):
    rng = np.random.default_rng(nb * hot)
    table = jnp.asarray(rng.normal(size=(V, dim)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, V, (nb, hot)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 1, (nb, hot)).astype(np.float32))
    mask = jnp.asarray(rng.random((nb, hot)) < 0.7)
    out = embedding_bag(table, idx, w, mask)
    ref = embedding_bag_ref(table, idx, w * mask.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def _merge_case(rng, R, L, k):
    """Sorted rows with sentinel heads + duplicate-heavy inserts."""
    pool = np.concatenate([[-2.0, -2.0],
                           np.round(rng.uniform(-1, 1, 8), 2)])
    vals = np.sort(rng.choice(pool, size=(R, L)).astype(np.float32), axis=1)
    idx = np.stack([rng.permutation(L).astype(np.int32) for _ in range(R)])
    ins_vals = np.round(rng.uniform(-1.9, 1, (R, k)), 2).astype(np.float32)
    ins_vals[0, 0] = vals[0, L // 2]             # tie vs an existing entry
    if k > 1:
        ins_vals[:, 1] = ins_vals[:, 0]          # tie between inserts
    ins_idx = np.broadcast_to(1000 + np.arange(k, dtype=np.int32), (R, k))
    ins_mask = rng.random((R, k)) < 0.7
    return (jnp.asarray(vals), jnp.asarray(idx), jnp.asarray(ins_vals),
            jnp.asarray(np.ascontiguousarray(ins_idx)),
            jnp.asarray(ins_mask))


@pytest.mark.parametrize("R,L,k", [(5, 12, 3), (9, 33, 7), (16, 64, 1),
                                   (3, 8, 8), (11, 130, 30), (8, 128, 5)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_merge_insert_sweep(R, L, k, use_pallas):
    rng = np.random.default_rng(R * 1000 + L + k)
    vals, idx, iv, ii, mask = _merge_case(rng, R, L, k)
    out_v, out_i = merge_insert(vals, idx, iv, ii, mask,
                                use_pallas=use_pallas)
    ref_v, ref_i = merge_insert_ref(vals, idx, iv, ii, mask)
    assert np.array_equal(np.asarray(out_v), np.asarray(ref_v))
    assert np.array_equal(np.asarray(out_i), np.asarray(ref_i))
    # merged rows stay ascending
    assert bool(jnp.all(out_v[:, 1:] >= out_v[:, :-1]))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_merge_insert_equals_sequential(use_pallas):
    """The batched merge == k sequential drop-min shift-inserts."""
    rng = np.random.default_rng(7)
    vals, idx, iv, ii, mask = _merge_case(rng, 6, 24, 5)
    seq_v, seq_i = np.asarray(vals).copy(), np.asarray(idx).copy()
    for t in range(5):
        for r in range(6):
            if not bool(mask[r, t]):
                continue
            s = float(iv[r, t])
            p = int(np.searchsorted(seq_v[r], s, side="right"))
            if p == 0:
                continue                          # below min: dropped
            seq_v[r] = np.concatenate([seq_v[r, 1:p], [s], seq_v[r, p:]])
            seq_i[r] = np.concatenate([seq_i[r, 1:p], [int(ii[r, t])],
                                       seq_i[r, p:]])
    out_v, out_i = merge_insert(vals, idx, iv, ii, mask,
                                use_pallas=use_pallas)
    assert np.array_equal(np.asarray(out_v), seq_v.astype(np.float32))
    assert np.array_equal(np.asarray(out_i), seq_i)


def _knn_case(rng, B, k, N, m):
    """Sparse ratings + clamped weights with dead (zero-weight) slots."""
    R = (rng.integers(1, 6, (N, m)) * (rng.random((N, m)) < 0.3)
         ).astype(np.float32)
    w = np.maximum(rng.normal(size=(B, k)), 0.0).astype(np.float32)
    nbrs = rng.integers(0, N, (B, k)).astype(np.int32)
    users = rng.integers(0, N, B).astype(np.int32)
    return (jnp.asarray(R), jnp.asarray(w), jnp.asarray(nbrs),
            jnp.asarray(users))


@pytest.mark.parametrize("B,k,N,m", [(4, 5, 30, 17), (16, 10, 120, 50),
                                     (1, 20, 64, 130), (7, 3, 50, 512),
                                     (13, 1, 16, 600)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_knn_scores_sweep(B, k, N, m, use_pallas):
    """Both backends (scan fast path / interpret-mode Pallas) are
    bit-exact against the einsum oracle."""
    rng = np.random.default_rng(B * 1000 + k * 100 + N + m)
    R, w, nbrs, users = _knn_case(rng, B, k, N, m)
    out = knn_scores(R, w, nbrs, users, use_pallas=use_pallas)
    ref = knn_scores_ref(R, w, nbrs, users)
    assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_knn_scores_zero_weight_slot_is_noop(use_pallas):
    """A weight-0 slot (SENTINEL/padded neighbour after clamping) must
    not perturb scores no matter which row it points at."""
    rng = np.random.default_rng(99)
    R, w, nbrs, users = _knn_case(rng, 6, 4, 40, 33)
    w = w.at[:, 2].set(0.0)
    a = knn_scores(R, w, nbrs, users, use_pallas=use_pallas)
    b = knn_scores(R, w, nbrs.at[:, 2].set(0), users,
                   use_pallas=use_pallas)
    assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_knn_recommend_topn_masks_seen(use_pallas):
    rng = np.random.default_rng(5)
    R, w, nbrs, users = _knn_case(rng, 5, 6, 30, 24)
    scores, items = knn_recommend_topn(R, w, nbrs, users, n_rec=7,
                                       use_pallas=use_pallas)
    ref = np.asarray(knn_scores_ref(R, w, nbrs, users))
    Rn, un = np.asarray(R), np.asarray(users)
    for b in range(5):
        order = np.argsort(-ref[b], kind="stable")[:7]
        assert np.array_equal(np.asarray(scores[b]), ref[b][order])
        finite = np.isfinite(np.asarray(scores[b]))
        assert np.all(Rn[un[b], np.asarray(items[b])[finite]] == 0)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 70), st.integers(2, 90),
       st.integers(2, 130))
def test_property_similarity_any_shape(seed, nq, n, m):
    rng = np.random.default_rng(seed)
    Q = jnp.asarray(rng.normal(size=(nq, m)).astype(np.float32))
    R = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    out = cosine_similarity(Q, R)
    assert out.shape == (nq, n)
    ref = similarity_ref(Q, R, jnp.linalg.norm(Q, axis=1),
                         jnp.linalg.norm(R, axis=1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40), st.integers(1, 9))
def test_property_bag_any_shape(seed, nb, hot):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, (nb, hot)).astype(np.int32))
    out = embedding_bag(table, idx)
    ref = embedding_bag_ref(table, idx, jnp.ones((nb, hot)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
