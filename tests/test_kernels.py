"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import (cosine_similarity, embedding_bag, twin_probe,
                           verify_rows)
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.similarity.ref import similarity_ref
from repro.kernels.twin_probe.ref import twin_probe_ref
from repro.kernels.verify_rows.ref import verify_rows_ref


@pytest.mark.parametrize("nq,n,m", [(8, 16, 32), (37, 451, 300),
                                    (128, 256, 512), (1, 943, 1682),
                                    (130, 259, 515)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_similarity_sweep(nq, n, m, dtype):
    rng = np.random.default_rng(nq * 1000 + n)
    Q = jnp.asarray(rng.normal(size=(nq, m)).astype(np.float32)).astype(
        dtype)
    R = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32)).astype(
        dtype)
    out = cosine_similarity(Q, R)
    qn = jnp.linalg.norm(Q.astype(jnp.float32), axis=1)
    rn = jnp.linalg.norm(R.astype(jnp.float32), axis=1)
    ref = similarity_ref(Q, R, qn, rn)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


@pytest.mark.parametrize("c,N", [(2, 64), (8, 700), (16, 2048), (8, 513)])
def test_twin_probe_sweep(c, N):
    rng = np.random.default_rng(c * N)
    rows = jnp.asarray(rng.uniform(0, 1, (c, N)).astype(np.float32))
    s0 = rows[:, N // 3]
    mask, count = twin_probe(rows, s0, tol=1e-6)
    mref, cref = twin_probe_ref(rows, s0, 1e-6)
    assert np.array_equal(np.asarray(mask), np.asarray(mref))
    assert int(count) == int(cref)


@pytest.mark.parametrize("s,m", [(8, 16), (37, 211), (256, 512), (300, 700)])
@pytest.mark.parametrize("dtype", [np.float32, np.int8])
def test_verify_rows_sweep(s, m, dtype):
    rng = np.random.default_rng(s * m)
    C = jnp.asarray(rng.integers(0, 6, (s, m)).astype(dtype))
    r0 = C[s // 2]
    valid = jnp.asarray(rng.random(s) < 0.8)
    out = verify_rows(C, r0, valid)
    ref = verify_rows_ref(C, r0, valid)[:, 0]
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("nb,hot,V,dim", [(4, 2, 50, 8), (16, 8, 1000, 128),
                                          (33, 5, 200, 64)])
def test_embedding_bag_sweep(nb, hot, V, dim):
    rng = np.random.default_rng(nb * hot)
    table = jnp.asarray(rng.normal(size=(V, dim)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, V, (nb, hot)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 1, (nb, hot)).astype(np.float32))
    mask = jnp.asarray(rng.random((nb, hot)) < 0.7)
    out = embedding_bag(table, idx, w, mask)
    ref = embedding_bag_ref(table, idx, w * mask.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 70), st.integers(2, 90),
       st.integers(2, 130))
def test_property_similarity_any_shape(seed, nq, n, m):
    rng = np.random.default_rng(seed)
    Q = jnp.asarray(rng.normal(size=(nq, m)).astype(np.float32))
    R = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    out = cosine_similarity(Q, R)
    assert out.shape == (nq, n)
    ref = similarity_ref(Q, R, jnp.linalg.norm(Q, axis=1),
                         jnp.linalg.norm(R, axis=1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40), st.integers(1, 9))
def test_property_bag_any_shape(seed, nb, hot):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, (nb, hot)).astype(np.int32))
    out = embedding_bag(table, idx)
    ref = embedding_bag_ref(table, idx, jnp.ones((nb, hot)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
