"""Distributed-path equivalence tests on a tiny 8-device debug mesh.

These run the *production* code paths (shard_map MoE EP, edge-parallel GAT,
distributed TwinSearch, buffered onboarding) against their portable
single-host references — the same invariants the 512-device dry-run relies
on, at pytest scale.  Spawned as a subprocess because the host-device-count
flag must be set before jax initialises (the rest of the suite needs 1
device).
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_debug_mesh

mesh = make_debug_mesh(multi_pod=True)
AX = ("pod", "data", "model")

# ---- MoE EP vs portable ----
from repro.configs.base import MoEConfig
from repro.models.moe import moe_ffn
from repro.models.moe_ep import moe_ffn_ep, MoEEPInfo
cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (4, 32, 16), jnp.float32)
rw = jax.random.normal(jax.random.PRNGKey(1), (16, 4)) * 0.1
wi = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 64)) * 0.1
wo = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16)) * 0.1
ref, _ = moe_ffn(x, rw, wi, wo, None, cfg, "swiglu", group_size=32)
info = MoEEPInfo(dp=("pod", "data"), mp="model", mp_size=2,
                 win_spec=P("model", None, None),
                 wout_spec=P("model", None, None),
                 acts_spec=P(("pod", "data"), "model", None), mesh=mesh)
with mesh:
    out, _ = jax.jit(lambda *a: moe_ffn_ep(*a, cfg, "swiglu", info))(
        x, rw, wi, wo)
assert float(jnp.max(jnp.abs(out - ref))) < 1e-5, "moe_ep mismatch"
print("moe_ep ok")

# ---- edge-parallel GAT vs portable (loss + grads) ----
from repro.configs.base import GNNConfig
from repro.models import gnn
from repro.models.gnn_ep import GNNEPInfo, loss_full_ep
gcfg = GNNConfig(name="g", n_layers=2, d_hidden=8, n_heads=8, n_classes=7)
N, E = 64, 192
p = gnn.init_params(key, gcfg, d_feat=16)
src = jnp.concatenate([jax.random.randint(key, (E,), 0, N), jnp.arange(N)])
dst = jnp.concatenate([jax.random.randint(jax.random.PRNGKey(9), (E,), 0, N),
                       jnp.arange(N)])
batch = {"feats": jax.random.normal(key, (N, 16)), "edge_src": src,
         "edge_dst": dst, "labels": jax.random.randint(key, (N,), 0, 7),
         "mask": jnp.ones(N, bool)}
rl, rg = jax.value_and_grad(gnn.loss_full)(p, batch, gcfg)
info = GNNEPInfo(axes=AX, mesh=mesh)
with mesh:
    gl, gg = jax.jit(jax.value_and_grad(
        lambda p, b: loss_full_ep(p, b, gcfg, info)))(p, batch)
assert abs(float(rl) - float(gl)) < 1e-5, "gnn_ep loss mismatch"
gd = max(float(jnp.max(jnp.abs(a - b)))
         for a, b in zip(jax.tree.leaves(rg), jax.tree.leaves(gg)))
assert gd < 1e-6, f"gnn_ep grad mismatch {gd}"
print("gnn_ep ok")

# ---- distributed TwinSearch vs buffered reference ----
from repro.core import build_state, make_probes, set0_cap
from repro.core.twinsearch import onboard_batch_buffered
from repro.core.twinsearch_sharded import onboard_batch_sharded
rng = np.random.default_rng(0)
n, m, k = 128, 32, 6
R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < 0.3)).astype(
    np.float32)
R[R.sum(1) == 0, 0] = 3.0
fresh = (rng.integers(1, 6, m) * (rng.random(m) < 0.4)).astype(np.float32)
fresh[0] = 2.0
R_new = np.stack([R[17], fresh, R[17], fresh, R[3], fresh])
probes = make_probes(jax.random.PRNGKey(1), k, 6, n)
s_max = set0_cap(n)
state = build_state(jnp.asarray(R), capacity_extra=0)
vA, iA, stA, (mvA, miA) = onboard_batch_buffered(
    state, jnp.asarray(R_new), probes, s_max=s_max, maintain=True)
with mesh:
    vB, iB, stB, (mvB, miB) = jax.jit(lambda st, rn, pr: onboard_batch_sharded(
        st, rn, pr, s_max=s_max, axes=AX, mesh=mesh, maintain=True))(
        state, jnp.asarray(R_new), probes)
assert np.allclose(np.asarray(vA), np.asarray(vB), atol=2e-5)
assert np.array_equal(np.asarray(stA.found), np.asarray(stB.found))
assert np.array_equal(np.asarray(stA.twin_idx), np.asarray(stB.twin_idx))
# maintained base lists: row-sharded merge == single-host merge
# (values to tolerance; ids may swap only across float ties, so check the
# membership invariant instead of bitwise idx equality)
assert np.allclose(np.asarray(mvA), np.asarray(mvB), atol=2e-5)
miB_np = np.asarray(miB)
for u in (0, 63, 127):
    for t in range(k):
        assert (miB_np[u] == n + t).sum() == 1
print("twinsearch_sharded ok")

# ---- resilient wrapper: heal poisoned rows from replicas, then the same
# sharded scan under the serving retry policy ----
from repro.core.twinsearch_sharded import onboard_batch_resilient
from repro.distributed.replication import ReplicatedArena, ReplicationConfig
from repro.serving.guard import RetryPolicy
replicas = ReplicatedArena(state, ReplicationConfig(n_shards=8, r=2))
sv = np.asarray(state.sim_vals).copy()
sv[5] = np.nan                               # a dead shard's garbage row
poisoned = state._replace(sim_vals=jnp.asarray(sv))
with mesh:
    healed, (vC, iC, stC) = onboard_batch_resilient(
        poisoned, jnp.asarray(R_new), probes, s_max=s_max, axes=AX,
        mesh=mesh, replicas=replicas,
        retry=RetryPolicy(max_attempts=2, base_delay_s=1e-4,
                          deadline_s=60.0, sleep=lambda s: None))
assert replicas.repaired_rows == 1, "poison not healed"
assert np.array_equal(np.asarray(healed.sim_vals),
                      np.asarray(state.sim_vals)), "heal not bit-exact"
assert np.allclose(np.asarray(vC), np.asarray(vA), atol=2e-5)
assert np.array_equal(np.asarray(stC.found), np.asarray(stA.found))
print("twinsearch_resilient ok")

# ---- one LM + one recsys cell lower+compile on the debug mesh ----
import dataclasses
from repro.configs import get_arch
from repro.configs.base import ShapeSpec, MoEConfig as MC
from repro.launch.steps import build_cell, jit_cell
spec = get_arch("olmoe-1b-7b")
small = dataclasses.replace(spec.config, n_layers=2, d_model=128, n_heads=4,
                            n_kv_heads=4, head_dim=32, vocab_size=512,
                            moe=MC(n_experts=4, top_k=2, d_ff_expert=64))
spec = dataclasses.replace(spec, config=small)
for sh in (ShapeSpec("t", "train", {"seq_len": 256, "global_batch": 8}),
           ShapeSpec("d", "decode", {"seq_len": 256, "global_batch": 8})):
    cell = build_cell(spec, sh, mesh)
    with mesh:
        jit_cell(cell, mesh).lower(*cell.args).compile()
print("lm cells ok")
print("ALL_OK")
"""


@pytest.mark.timeout(900)
def test_distributed_paths_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=880)
    assert "ALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
