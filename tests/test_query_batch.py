"""Batched query path: core batch endpoints, twin dedup, per-row guards.

Covers the PR-10 contracts:

  * ``top_k_neighbors`` with ``k > n_active - 1`` never leaks SENTINEL
    arena rows as neighbours (regression: padded/dead rows used to
    surface with sentinel weights and poison downstream gathers);
  * batched == scalar *bit-exact* on random states (``recommend_batch``
    / ``predict_batch`` are vmapped scalar paths, not approximations);
  * twin users (bitwise-identical dedup keys) provably share scores and
    are scored once;
  * a forced hash collision in the dedup probe never causes wrong
    sharing — the exact-verify step keeps distinct rows distinct;
  * a mixed valid/invalid batch quarantines the bad rows and serves the
    rest (no-raise contract extends to reads);
  * the shed rung degrades reads (smaller k) instead of refusing them.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SENTINEL_GATE, build_state, knn
from repro.serving import CFServer, LEVEL_SHED, ServerConfig
from repro.serving import dedup as dedup_mod
from repro.serving.dedup import dedup_rows, fan_out


def _ratings(rng, n, m, density=0.3):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R


def _state(R, extra=8):
    return jax.block_until_ready(
        jax.jit(lambda r: build_state(r, capacity_extra=extra))(
            jnp.asarray(R)))


class TestTopKSmallActive:
    def test_k_exceeding_active_never_leaks_sentinel_rows(self):
        """k > n_active - 1: dead slots must gate to weight-SENTINEL and
        clamp to row 0, never expose padded arena rows."""
        rng = np.random.default_rng(0)
        n = 3
        R = _ratings(rng, n, 12)
        state = _state(R, extra=29)          # capacity 32 >> n_active 3
        for user in range(n):
            sims, nbrs = jax.device_get(
                knn.top_k_neighbors(state, jnp.int32(user), k=20))
            live = sims > SENTINEL_GATE
            assert live.sum() <= n - 1       # at most the other real users
            assert np.all(nbrs[live] < n)
            assert np.all(nbrs[live] != user)
            assert np.all(nbrs[~live] == 0)  # dead slots clamp to row 0

    def test_predictions_well_defined_with_oversized_k(self):
        rng = np.random.default_rng(1)
        R = _ratings(rng, 4, 10, density=0.9)
        state = _state(R, extra=28)
        p = float(knn.predict(state, jnp.int32(0), jnp.int32(3), k=25))
        assert np.isfinite(p)
        scores, items = jax.device_get(
            knn.recommend(state, jnp.int32(1), k_neighbors=25, n_rec=4))
        assert np.all(np.asarray(items) < 10)

    def test_matches_small_k_on_shared_prefix(self):
        """The first min(k, n_active-1) slots agree with a small-k call."""
        rng = np.random.default_rng(2)
        R = _ratings(rng, 5, 16)
        state = _state(R, extra=27)
        s_small, n_small = jax.device_get(
            knn.top_k_neighbors(state, jnp.int32(2), k=4))
        s_big, n_big = jax.device_get(
            knn.top_k_neighbors(state, jnp.int32(2), k=30))
        assert np.array_equal(s_small, s_big[:4])
        assert np.array_equal(n_small, n_big[:4])


class TestBatchedEqualsScalar:
    @pytest.mark.parametrize("seed,n,m", [(0, 20, 30), (1, 64, 17),
                                          (2, 7, 50)])
    def test_recommend_batch_bit_exact(self, seed, n, m):
        rng = np.random.default_rng(seed)
        state = _state(_ratings(rng, n, m))
        users = jnp.asarray(rng.integers(0, n, 13).astype(np.int32))
        bs, bi = jax.device_get(
            knn.recommend_batch(state, users, k_neighbors=5, n_rec=6))
        for r, u in enumerate(np.asarray(users)):
            ss, si = jax.device_get(
                knn.recommend(state, jnp.int32(int(u)), 5, 6))
            assert bs[r].tobytes() == np.asarray(ss).tobytes()
            assert np.array_equal(bi[r], np.asarray(si))

    @pytest.mark.parametrize("seed,n,m", [(3, 24, 40), (4, 9, 9)])
    def test_predict_batch_bit_exact(self, seed, n, m):
        rng = np.random.default_rng(seed)
        state = _state(_ratings(rng, n, m))
        users = rng.integers(0, n, 11).astype(np.int32)
        items = rng.integers(0, m, 11).astype(np.int32)
        bp = jax.device_get(knn.predict_batch(
            state, jnp.asarray(users), jnp.asarray(items), k=4))
        for r in range(11):
            sp = jax.device_get(knn.predict(
                state, jnp.int32(int(users[r])), jnp.int32(int(items[r])),
                k=4))
            assert bp[r].tobytes() == np.asarray(sp).tobytes()

    def test_server_batch_equals_server_scalar(self):
        rng = np.random.default_rng(5)
        R = _ratings(rng, 30, 25)
        srv = CFServer(R, ServerConfig(capacity_extra=8))
        users = rng.integers(0, 30, 9)
        batch = srv.recommend_batch(users, n=5, k_neighbors=6)
        for u, row in zip(users, batch):
            assert srv.recommend(int(u), n=5, k_neighbors=6) == row
        items = rng.integers(0, 25, 9)
        preds = srv.predict_batch(users, items, k=6)
        for u, it, p in zip(users, items, preds):
            assert srv.predict(int(u), int(it), k=6) == p


class TestTwinDedup:
    def test_twin_users_share_scores_and_score_once(self):
        """Bitwise-identical rating rows are provably twins: identical
        sims, neighbour lists, and own-row keys -> one scored row."""
        rng = np.random.default_rng(6)
        R = _ratings(rng, 12, 18, density=0.5)
        R[7] = R[3]
        R[9] = R[3]                          # users 3, 7, 9 are twins
        srv = CFServer(R, ServerConfig(capacity_extra=8))
        users = [3, 7, 9, 3, 1, 9]
        out = srv.recommend_batch(users, n=4, k_neighbors=5)
        assert out[0] == out[1] == out[2] == out[3] == out[5]
        assert srv.stats.query_unique < srv.stats.queries
        assert srv.stats.query_dedup_savings[-1] > 0

    def test_dedup_rows_collapses_only_identical(self):
        rows = np.asarray([[1.0, 2.0], [1.0, 2.0], [1.0, 2.5], [1.0, 2.0]],
                          np.float32)
        plan = dedup_rows(rows)
        assert plan.n_unique == 2
        fanned = fan_out(np.asarray([f"row{i}"
                                     for i in range(plan.n_unique)]), plan)
        assert fanned[0] == fanned[1] == fanned[3]
        assert fanned[2] != fanned[0]

    def test_forced_hash_collision_never_shares_wrongly(self, monkeypatch):
        """Degrade the probe hash to a constant: every row lands in one
        bucket, and only the exact-verify step separates them."""
        monkeypatch.setattr(
            dedup_mod, "_fnv1a",
            lambda cols: np.zeros(cols.shape[0], np.uint32))
        rng = np.random.default_rng(7)
        rows = rng.normal(size=(32, 6)).astype(np.float32)
        rows[5] = rows[2]                    # one genuine twin pair
        plan = dedup_mod.dedup_rows(rows)
        assert plan.n_unique == 31
        rebuilt = plan.unique_rows[plan.scatter]
        assert np.array_equal(rows[rebuilt], rows)
        # end-to-end: server answers are still per-user correct
        R = _ratings(rng, 10, 14)
        srv = CFServer(R, ServerConfig(capacity_extra=4))
        users = list(range(8))
        batch = srv.recommend_batch(users, n=3, k_neighbors=4)
        for u, row in zip(users, batch):
            assert srv.recommend(u, n=3, k_neighbors=4) == row

    def test_distinct_users_not_collapsed(self):
        rng = np.random.default_rng(8)
        R = _ratings(rng, 16, 20, density=0.8)
        srv = CFServer(R, ServerConfig(capacity_extra=4))
        srv.recommend_batch(list(range(16)), n=4, k_neighbors=5)
        # dense distinct rows -> overwhelmingly distinct keys
        assert srv.stats.query_unique >= 15


class TestPerRowGuard:
    def test_mixed_batch_quarantines_and_serves(self):
        rng = np.random.default_rng(9)
        R = _ratings(rng, 20, 15)
        srv = CFServer(R, ServerConfig(capacity_extra=4))
        before = srv.quarantine.total
        out = srv.recommend_batch([4, -1, 10**9, 7, "junk"], n=3,
                                  k_neighbors=5)
        assert out[1] == [] and out[2] == [] and out[4] == []
        assert out[0] == srv.recommend(4, n=3, k_neighbors=5)
        assert out[3] == srv.recommend(7, n=3, k_neighbors=5)
        assert srv.quarantine.total >= before + 3
        assert srv.stats.queries >= 2        # only valid rows counted

    def test_predict_batch_bad_item_row(self):
        rng = np.random.default_rng(10)
        R = _ratings(rng, 12, 10)
        srv = CFServer(R, ServerConfig(capacity_extra=4))
        out = srv.predict_batch([3, 5, 2], [4, 9999, -1], k=4)
        assert out[1] == 0.0 and out[2] == 0.0
        assert out[0] == srv.predict(3, 4, k=4)

    def test_all_invalid_batch_is_cheap_noop(self):
        rng = np.random.default_rng(11)
        srv = CFServer(_ratings(rng, 8, 8), ServerConfig(capacity_extra=4))
        batches_before = srv.stats.query_batches
        assert srv.recommend_batch([-1, 99999]) == [[], []]
        assert srv.predict_batch([-5], [2]) == [0.0]
        assert srv.stats.query_batches == batches_before  # never dispatched


class TestShedDegradesReads:
    def test_shed_serves_reads_at_reduced_k(self):
        rng = np.random.default_rng(12)
        R = _ratings(rng, 20, 16)
        srv = CFServer(R, ServerConfig(capacity_extra=4))
        srv.level = LEVEL_SHED
        out = srv.recommend_batch([1, 2, 3], n=3, k_neighbors=8)
        assert all(len(r) == 3 for r in out)          # served, not refused
        assert srv.stats.query_degraded == 3
        assert srv._query_k(8) == 2                   # 8 // SHED_QUERY_K_DIV
        assert srv._query_k(3) == 1                   # floor at 1
        s = srv.stats.summary()
        for key in ("queries", "query_batches", "query_unique",
                    "query_degraded", "query_p50_ms", "query_p99_ms",
                    "query_dedup_savings"):
            assert key in s
