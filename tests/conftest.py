"""Shared fixtures: reduced per-family configs for the CPU smoke tests.

The FULL assigned configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation); tests instantiate the same *family
structure* (MoE vs dense, MQA vs MHA, window pattern, CIN depth, tower
shapes) at tiny dims.  XLA_FLAGS must stay unset here — smoke tests and
benches see the 1 real CPU device (the dry-run sets 512 itself).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import (ArchSpec, LMConfig, MoEConfig, RecsysConfig,
                                ShapeSpec)
from repro.configs._fields import powerlaw_vocabs


def tiny_lm(cfg: LMConfig) -> LMConfig:
    """Shrink dims, keep structure (MoE/GQA ratio/window pattern/act)."""
    unit = cfg.global_every or 1
    n_layers = max(2, 2 * unit) if unit > 1 else 2
    n_kv = 1 if cfg.n_kv_heads == 1 else (4 if cfg.n_kv_heads ==
                                          cfg.n_heads else 2)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=4, top_k=min(2, cfg.moe.top_k),
                        d_ff_expert=64, n_shared=cfg.moe.n_shared)
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=64, n_heads=4, n_kv_heads=n_kv,
        head_dim=16, d_ff=128, vocab_size=512, moe=moe,
        window=(8 if cfg.window is not None else None),
        global_every=cfg.global_every)


def tiny_recsys(cfg: RecsysConfig) -> RecsysConfig:
    changes: dict = {}
    if cfg.field_vocab_sizes:
        changes["field_vocab_sizes"] = powerlaw_vocabs(
            len(cfg.field_vocab_sizes), largest=500, smallest=8, n_large=2)
    if cfg.item_vocab:
        changes["item_vocab"] = 1000
    if cfg.user_vocab:
        changes["user_vocab"] = 1000
    if cfg.mlp_dims:
        changes["mlp_dims"] = tuple(min(64, d) for d in cfg.mlp_dims)
    if cfg.cin_layers:
        changes["cin_layers"] = tuple(min(16, h) for h in cfg.cin_layers)
    if cfg.tower_mlp:
        changes["tower_mlp"] = (64, 32)
    return dataclasses.replace(cfg, **changes)


def reduced_spec(arch_id: str) -> ArchSpec:
    spec = get_arch(arch_id)
    if spec.family == "lm":
        return dataclasses.replace(spec, config=tiny_lm(spec.config))
    if spec.family == "recsys":
        return dataclasses.replace(spec, config=tiny_recsys(spec.config))
    return spec                     # gnn / cf configs are already small


# CI's chaos step re-runs the fault suite under a seed matrix
# (REPRO_TEST_SEED=0/1/2); every test stays deterministic per seed.
TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


@pytest.fixture
def rng():
    return np.random.default_rng(TEST_SEED)


def make_ratings(rng, n=120, m=40, density=0.3):
    R = (rng.integers(1, 6, (n, m)) * (rng.random((n, m)) < density)
         ).astype(np.float32)
    R[R.sum(axis=1) == 0, 0] = 3.0
    return R
