"""End-to-end behaviour tests for the paper's system: the full journey a
production deployment exercises — build, onboard (both paths), query,
update, checkpoint the serving state, and restore."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import build_state, knn
from repro.serving import CFServer
from repro.training import checkpoint
from tests.conftest import make_ratings


def test_full_system_journey(rng, tmp_path):
    R = make_ratings(rng, n=150, m=50)
    srv = CFServer(R, capacity_extra=16, c_probes=6)

    # 1. onboard a twin burst (the paper's special case)
    for i in range(5):
        uid, info = srv.onboard_user(R[33])
        assert info["twin_found"]

    # 2. recommendations flow for the new users immediately
    recs = srv.recommend(152, n=5)
    assert len(recs) == 5 and all(R[33][i] == 0 for i, _ in recs)

    # 3. the new users' neighbourhoods contain their twins at sim 1.0
    sims, nbrs = knn.top_k_neighbors(srv.state, jnp.int32(151), 4)
    assert 33 in np.asarray(nbrs) or 150 in np.asarray(nbrs)
    assert float(sims[0]) == pytest.approx(1.0, abs=1e-5)

    # 4. a rating update shifts the affected user's similarity row
    before = np.asarray(srv.state.sim_vals[10]).copy()
    srv.add_rating(10, 3, 5.0)
    after = np.asarray(srv.state.sim_vals[10])
    assert not np.allclose(before, after)

    # 5. checkpoint the serving state, restore, answers unchanged
    checkpoint.save(str(tmp_path), 1, srv.state._asdict())
    restored, step, _ = checkpoint.restore(str(tmp_path),
                                           srv.state._asdict())
    np.testing.assert_allclose(np.asarray(restored["sim_vals"]),
                               np.asarray(srv.state.sim_vals), atol=1e-6)


def test_build_matches_oracle_end_to_end(rng):
    from repro.core.reference import build_sorted_lists_np
    R = make_ratings(rng, n=60, m=25)
    state = build_state(jnp.asarray(R))
    sv, si = build_sorted_lists_np(R)
    np.testing.assert_allclose(np.asarray(state.sim_vals), sv, atol=1e-5)
