"""Fault-injection suite: the CF serving path under hostile conditions.

Contract under test (ISSUE 7): ``CFServer`` never raises to the caller —
capacity overflow rotates the arena, malformed requests are quarantined,
latency spikes walk the degradation ladder, transient executor faults
retry, and a poisoned arena (bit-flips / simulated shard loss) is detected
and rolled back to the last good snapshot.  All faults come from the
deterministic harness in ``repro/testing/faults.py``.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import rotate_arena, unsorted_rows
from repro.core.similarity import cosine_matrix
from repro.core.types import SENTINEL_GATE
from repro.kernels.verify_rows.ops import arena_healthy, rows_sorted_finite
from repro.kernels.verify_rows.ref import rows_sorted_finite_ref
from repro.serving import CFServer, ServerStats
from repro.serving.guard import RetryPolicy
from repro.testing import (FakeClock, Flaky, MalformedRequests,
                           capacity_flood, inject_latency, poison_state)
from repro.training import checkpoint
from repro.training.elastic import Action, StragglerMonitor
from tests.conftest import make_ratings

pytestmark = pytest.mark.faults


def _unsorted_active(state, n_act):
    """(n_act, n_act) unsorted similarity block recovered from the lists."""
    rows = unsorted_rows(state.sim_vals, state.sim_idx,
                         jnp.arange(n_act, dtype=jnp.int32))
    return np.asarray(rows)[:, :n_act]


# ---------------------------------------------------------------------------
# Guard + quarantine
# ---------------------------------------------------------------------------

class TestGuardQuarantine:
    def test_malformed_onboards_never_raise(self, rng):
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, c_probes=4)
        mal = MalformedRequests(16, seed=1)
        for name, bad in mal.everything():
            uid, info = srv.onboard_user(bad)
            assert uid == -1 and info["status"] == "rejected", name
        assert srv.stats.rejected == 7
        assert srv.quarantine.total == 7
        # one rejection per failure mode, keyed by stable reason strings
        assert set(srv.quarantine.counts) == {
            "non_finite", "shape", "dtype", "range", "empty"}
        # nothing malformed reached the arena: still healthy, still serving
        assert bool(arena_healthy(srv.state.sim_vals, srv.state.ratings,
                                  srv.state.norms, srv.state.n_active))
        uid, info = srv.onboard_user(R[3])
        assert uid == 40 and info["status"] == "ok"

    def test_query_and_update_guards(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4)
        assert srv.recommend(-1) == []
        assert srv.recommend(10_000) == []
        assert srv.predict(5, 10_000) == 0.0
        assert not srv.add_rating(5, 3, float("nan"))
        assert not srv.add_rating(5, 3, 99.0)
        assert not srv.add_rating("x", 3, 4.0)
        assert srv.stats.rejected == 6
        assert srv.add_rating(5, 3, 4.0)          # valid still goes through
        assert float(srv.state.ratings[5, 3]) == 4.0

    def test_quarantine_ring_is_bounded(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, quarantine_capacity=5)
        for _ in range(20):
            srv.onboard_user(np.full(10, np.nan, np.float32))
        assert len(srv.quarantine.records) == 5
        assert srv.quarantine.total == 20


# ---------------------------------------------------------------------------
# Arena rotation
# ---------------------------------------------------------------------------

class TestArenaRotation:
    def test_overflow_rotates_instead_of_raising(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=1)
        srv.onboard_user(R[0])                    # fills the only slot
        uid, info = srv.onboard_user(R[1])        # used to RuntimeError
        assert uid == 21 and info["status"] == "ok"
        assert srv.stats.rotations == 1
        assert srv.n_base == 21 and srv.state.capacity == 22

    def test_flood_past_capacity(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4, c_probes=4)
        results = capacity_flood(srv, R, 14, seed=3)
        uids = [u for u, info in results]
        assert all(info["status"] == "ok" for _, info in results)
        assert uids == list(range(30, 44))         # monotonic, no gaps
        assert srv.stats.rotations == 3            # 4-slot arena, 14 users
        assert int(srv.state.n_active) == 44
        recs = srv.recommend(43, n=5)
        assert len(recs) == 5

    def test_rotation_bit_exact_data_movement(self, rng):
        """Rotated base lists must be a pure rearrangement: bitwise equal
        to a numpy re-sort of (gated base entries + recovered buffer sims)
        — no similarity arithmetic happens during rotation."""
        R = make_ratings(rng, n=25, m=12)
        srv = CFServer(R, capacity_extra=4, c_probes=4)
        for i in (3, 7, 3, 11):                    # mix of twins + fresh
            srv.onboard_user(R[i])
        st = srv.state
        n_base, n_act, extra = 25, 29, 4
        U = np.asarray(unsorted_rows(
            st.sim_vals, st.sim_idx,
            jnp.arange(n_base, n_act, dtype=jnp.int32)))
        rot = rotate_arena(st, n_base=n_base, extra=extra)
        assert rot.capacity == n_act + extra
        for x in range(n_base):
            vals = np.asarray(st.sim_vals[x])
            idx = np.asarray(st.sim_idx[x])
            keep = idx < n_base                    # pre-rotation real entries
            ref = np.sort(np.concatenate(
                [vals[keep], U[:, x].astype(vals.dtype)]))
            row = np.asarray(rot.sim_vals[x])
            np.testing.assert_array_equal(row[-ref.shape[0]:], ref)
            ridx = np.asarray(rot.sim_idx[x])
            real = row > SENTINEL_GATE
            assert set(ridx[real]) == set(range(n_act))

    def test_rotated_arena_matches_fresh_traditional_build(self, rng):
        R = make_ratings(rng, n=25, m=12)
        srv = CFServer(R, capacity_extra=5, c_probes=4)
        fresh = make_ratings(np.random.default_rng(7), n=3, m=12)
        for r in (R[3], fresh[0], R[3], fresh[1], fresh[2]):
            srv.onboard_user(r)
        srv.onboard_user(R[8])                     # triggers rotation
        assert srv.stats.rotations == 1
        n_act = int(srv.state.n_active)
        S_ref = np.asarray(cosine_matrix(srv.state.ratings[:n_act]))
        # The compacted region (everything rotated into the base) is
        # all-pairs complete and matches a fresh traditional build ...
        nb = srv.n_base
        S_rot = _unsorted_active(srv.state, n_act)
        np.testing.assert_allclose(S_rot[:nb, :nb], S_ref[:nb, :nb],
                                   atol=1e-5)
        np.testing.assert_allclose(S_rot[nb:, :nb], S_ref[nb:, :nb],
                                   atol=1e-5)      # new rows vs base
        # ... and the post-rotation onboard's deferred symmetric entries
        # land on the next compaction: rotating once more yields the full
        # fresh matrix.
        full = rotate_arena(srv.state, n_base=nb, extra=0)
        S_full = _unsorted_active(full, n_act)
        np.testing.assert_allclose(S_full, S_ref, atol=1e-5)
        # rows stay ascending and healthy after rotation
        assert bool(arena_healthy(srv.state.sim_vals, srv.state.ratings,
                                  srv.state.norms, srv.state.n_active))

    def test_rotation_gates_refreshed_rows(self, rng):
        """A base row re-sorted by add_rating already contains write-region
        entries; rotation must not duplicate them."""
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, c_probes=4)
        srv.onboard_user(R[2])
        srv.add_rating(5, 3, 4.0)                  # row 5 now sees user 20
        srv.onboard_user(R[6])                     # fills the arena
        srv.onboard_user(R[9])                     # rotates, then onboards
        assert srv.stats.rotations == 1
        idx = np.asarray(srv.state.sim_idx)
        vals = np.asarray(srv.state.sim_vals)
        n_act = int(srv.state.n_active)
        for x in range(n_act):
            real = idx[x][vals[x] > SENTINEL_GATE]
            assert len(real) == len(set(real)), f"duplicate ids in row {x}"


# ---------------------------------------------------------------------------
# Degradation ladder (latency spikes, virtual time)
# ---------------------------------------------------------------------------

class TestDegradationLadder:
    def _server(self, R, clock, **kw):
        mon = StragglerMonitor(window=20, straggler_ratio=2.0,
                               hang_timeout_s=1000.0,
                               consecutive_to_shrink=2, clock=clock)
        return CFServer(R, capacity_extra=64, c_probes=4, monitor=mon,
                        snapshot_every=10_000, check_every=10_000, **kw)

    def test_spikes_step_down_ladder_then_recover(self, rng):
        R = make_ratings(rng, n=40, m=16)
        clock = FakeClock()
        srv = self._server(R, clock, recover_after=5, shed_cooldown_s=10.0)
        inject_latency(srv, clock, [0.1] * 12 + [1.0] * 4 + [0.1] * 30)
        for i in range(16):
            _, info = srv.onboard_user(R[i % 40])
            assert info["status"] == "ok"
        # two straggler verdicts: twinsearch -> traditional -> shed
        assert srv.stats.degradations == 2
        assert srv.level == 2

        # shed: backpressure, no work, no raise
        uid, info = srv.onboard_user(R[0])
        assert uid == -1 and info["status"] == "shed"
        assert info["retry_after_s"] > 0
        assert srv.stats.shed == 1

        # cooldown expiry probes traditional again, healthy streak recovers
        clock.advance(11.0)
        _, info = srv.onboard_user(R[0])
        assert info["status"] == "ok" and srv.level == 1
        for i in range(6):
            srv.onboard_user(R[i])
        assert srv.level == 0
        assert srv.stats.recoveries == 2

    def test_hang_sheds_immediately(self, rng):
        R = make_ratings(rng, n=40, m=16)
        clock = FakeClock()
        mon = StragglerMonitor(window=20, straggler_ratio=2.0,
                               hang_timeout_s=5.0,
                               consecutive_to_shrink=2, clock=clock)
        srv = CFServer(R, capacity_extra=16, c_probes=4, monitor=mon,
                       snapshot_every=10_000, check_every=10_000)
        inject_latency(srv, clock, [0.1] * 10 + [60.0])
        for i in range(10):
            srv.onboard_user(R[i])
        assert srv.level == 0
        _, info = srv.onboard_user(R[10])          # hang-scale latency
        assert info["status"] == "ok"              # the call did finish...
        assert srv.level == 2                      # ...but ABORT -> shed


# ---------------------------------------------------------------------------
# Retry / transient executor faults
# ---------------------------------------------------------------------------

class TestRetry:
    def test_transient_fault_retries_to_success(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4,
                       retry=RetryPolicy(max_attempts=4, base_delay_s=1e-4,
                                         deadline_s=10.0,
                                         sleep=lambda s: None))
        srv._onboard = Flaky(srv._onboard, fail_times=2)
        uid, info = srv.onboard_user(R[0])
        assert uid == 30 and info["status"] == "ok"
        assert srv.stats.retries == 2

    def test_permanent_fault_is_quarantined_not_raised(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4,
                       retry=RetryPolicy(max_attempts=3, base_delay_s=1e-4,
                                         deadline_s=10.0,
                                         sleep=lambda s: None))
        srv._onboard = Flaky(srv._onboard, fail_times=99)
        uid, info = srv.onboard_user(R[0])
        assert uid == -1 and info["status"] == "error"
        assert srv.stats.errors == 1
        assert srv.quarantine.counts["error"] == 1
        # state untouched by the failed attempts (functional updates)
        assert int(srv.state.n_active) == 30
        srv._build_jits()                          # drop the fault wrapper
        uid, info = srv.onboard_user(R[0])
        assert uid == 30 and info["status"] == "ok"


# ---------------------------------------------------------------------------
# Snapshot / rollback (state poisoning, simulated shard loss)
# ---------------------------------------------------------------------------

class TestSnapshotRollback:
    def test_poisoned_lists_roll_back(self, rng, tmp_path):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=8, snapshot_every=3, check_every=1,
                       snapshot_dir=str(tmp_path))
        for i in range(4):
            srv.onboard_user(R[i])
        good_n = int(srv.state.n_active)
        assert checkpoint.all_steps(str(tmp_path))  # disk snapshots landed

        poison_state(srv, rows=[2, 17])            # bit-flip corruption
        uid, info = srv.onboard_user(R[5])
        assert uid == -1 and info["status"] == "rolled_back"
        assert srv.stats.rollbacks == 1
        assert int(srv.state.n_active) <= good_n
        assert bool(arena_healthy(srv.state.sim_vals, srv.state.ratings,
                                  srv.state.norms, srv.state.n_active))
        uid, info = srv.onboard_user(R[5])         # back in business
        assert info["status"] == "ok"
        assert len(srv.recommend(int(srv.state.n_active) - 1, n=3)) == 3

    def test_simulated_shard_loss_rolls_back(self, rng):
        R = make_ratings(rng, n=32, m=12)
        srv = CFServer(R, capacity_extra=8, snapshot_every=2, check_every=1)
        for i in range(3):
            srv.onboard_user(R[i])
        # shard 2 of 4 dies; its row-shard of the ratings arena is garbage
        lost = poison_state(srv, shard=2, n_shards=4, field="ratings")
        assert lost.shape[0] == 10                 # 40-row arena / 4
        uid, info = srv.onboard_user(R[7])
        assert uid == -1 and info["status"] == "rolled_back"
        assert srv.stats.rollbacks == 1
        uid, info = srv.onboard_user(R[7])
        assert info["status"] == "ok"

    def test_rollback_across_rotation_restores_geometry(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, snapshot_every=10_000,
                       check_every=1)
        cap0, nb0 = srv.state.capacity, srv.n_base
        for i in range(5):                         # forces rotations
            srv.onboard_user(R[i])
        assert srv.stats.rotations >= 1
        assert srv.state.capacity > cap0
        poison_state(srv, rows=[1])
        _, info = srv.onboard_user(R[6])
        assert info["status"] == "rolled_back"
        # only the construction snapshot existed: geometry rolled back too
        assert srv.state.capacity == cap0 and srv.n_base == nb0
        _, info = srv.onboard_user(R[6])
        assert info["status"] == "ok"


# ---------------------------------------------------------------------------
# Invariant-check op (verify_rows family)
# ---------------------------------------------------------------------------

class TestHealthOp:
    def test_rows_sorted_finite_matches_ref(self, rng):
        vals = np.sort(rng.normal(size=(8, 16)).astype(np.float32), axis=1)
        vals[2, 5] = np.nan                        # live + non-finite
        vals[4, 3], vals[4, 4] = vals[4, 4], vals[4, 3]   # live + unsorted
        vals[6, 0] = np.inf                        # unsorted AND non-finite
        live = np.arange(8) < 7                    # row 7 is dead
        vals[7, :] = np.nan                        # dead rows never flag
        got = np.asarray(rows_sorted_finite(jnp.asarray(vals), jnp.int32(7)))
        ref = np.asarray(rows_sorted_finite_ref(jnp.asarray(vals),
                                                jnp.asarray(live)))
        np.testing.assert_array_equal(got, ref)
        np.testing.assert_array_equal(
            got, [True, True, False, True, False, True, False, True])

    def test_arena_healthy_gates(self, rng):
        R = make_ratings(rng, n=16, m=8)
        srv = CFServer(R, capacity_extra=2)
        st = srv.state
        ok = lambda s: bool(arena_healthy(s.sim_vals, s.ratings, s.norms,
                                          s.n_active))
        assert ok(st)
        assert not ok(st._replace(
            norms=st.norms.at[3].set(jnp.float32(jnp.nan))))
        assert not ok(st._replace(n_active=jnp.int32(99)))
        bad = st.sim_vals.at[0, 0].set(jnp.float32(5.0))   # > all: unsorted
        assert not ok(st._replace(sim_vals=bad))


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------

class TestSatellites:
    def test_onboard_ms_is_bounded_ring(self):
        stats = ServerStats(latency_window=8)
        for i in range(100):
            stats.onboard_ms.append(float(i))
        assert len(stats.onboard_ms) == 8
        s = stats.summary()
        # percentiles over the trailing window only (92..99)
        assert s["onboard_p50_ms"] == 96.0
        assert s["onboard_p99_ms"] == 99.0

    def test_straggler_finish_without_start(self):
        mon = StragglerMonitor()
        assert mon.step_finished() is Action.CONTINUE
        assert mon.stats() == {}                   # no sample recorded
        mon.step_started()
        assert mon.step_finished() is Action.CONTINUE
        assert mon.step_finished() is Action.CONTINUE   # double-finish too
        assert mon.stats()["n"] == 1

    def test_add_rating_jit_hoisted(self, rng):
        R = make_ratings(rng, n=12, m=8)
        srv = CFServer(R, capacity_extra=2)
        # jits exist before any call — a first-call failure can't leave the
        # server half-initialised
        for attr in ("_add", "_init_cache", "_onboard", "_onboard_trad",
                     "_recommend", "_predict"):
            assert hasattr(srv, attr), attr
        assert srv._cache is None                  # cache itself stays lazy
