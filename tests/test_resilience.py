"""Fault-injection suite: the CF serving path under hostile conditions.

Contract under test (ISSUE 7 + 8): ``CFServer`` never raises to the
caller — capacity overflow rotates the arena, malformed requests are
quarantined, latency spikes walk the degradation ladder, transient
executor faults retry, and a poisoned arena (bit-flips / simulated shard
loss) is healed from replicas or rolled back to the last good snapshot.
A simulated crash at any injected crash point recovers bit-exactly via
WAL replay over the newest checkpoint; losing any single replica keeps
the server available while re-replication restores redundancy with zero
similarity math.  All faults come from the deterministic harness in
``repro/testing/faults.py``.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (RotationPlan, rotate_arena, rotate_arena_frozen,
                        unsorted_rows)
from repro.core.similarity import cosine_matrix
from repro.core.types import SENTINEL_GATE
from repro.distributed import (ReplicaState, ReplicatedArena,
                               ReplicationConfig)
from repro.kernels.verify_rows.ops import arena_healthy, rows_sorted_finite
from repro.kernels.verify_rows.ref import rows_sorted_finite_ref
from repro.serving import (CFServer, RotationConfig, ServerConfig,
                           ServerStats, SnapshotConfig, WalConfig,
                           WriteAheadLog,
                           LEVEL_DEGRADED, LEVEL_SHED, LEVEL_TRADITIONAL,
                           LEVEL_TWINSEARCH)
from repro.serving.guard import RetryPolicy
from repro.testing import (CRASH_POINTS, ROTATION_CRASH_POINTS, FakeClock,
                           Flaky,
                           MalformedRequests, SimulatedCrash,
                           capacity_flood, forbid_similarity_kernels,
                           inject_latency, install_crash, kill_replica,
                           poison_state)
from repro.training import checkpoint
from repro.training.elastic import Action, StragglerMonitor
from tests.conftest import make_ratings

pytestmark = pytest.mark.faults


def _unsorted_active(state, n_act):
    """(n_act, n_act) unsorted similarity block recovered from the lists."""
    rows = unsorted_rows(state.sim_vals, state.sim_idx,
                         jnp.arange(n_act, dtype=jnp.int32))
    return np.asarray(rows)[:, :n_act]


# ---------------------------------------------------------------------------
# Guard + quarantine
# ---------------------------------------------------------------------------

class TestGuardQuarantine:
    def test_malformed_onboards_never_raise(self, rng):
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, c_probes=4)
        mal = MalformedRequests(16, seed=1)
        for name, bad in mal.everything():
            uid, info = srv.onboard_user(bad)
            assert uid == -1 and info["status"] == "rejected", name
        assert srv.stats.rejected == 7
        assert srv.quarantine.total == 7
        # one rejection per failure mode, keyed by stable reason strings
        assert set(srv.quarantine.counts) == {
            "non_finite", "shape", "dtype", "range", "empty"}
        # nothing malformed reached the arena: still healthy, still serving
        assert bool(arena_healthy(srv.state.sim_vals, srv.state.ratings,
                                  srv.state.norms, srv.state.n_active))
        uid, info = srv.onboard_user(R[3])
        assert uid == 40 and info["status"] == "ok"

    def test_query_and_update_guards(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4)
        assert srv.recommend(-1) == []
        assert srv.recommend(10_000) == []
        assert srv.predict(5, 10_000) == 0.0
        assert not srv.add_rating(5, 3, float("nan"))
        assert not srv.add_rating(5, 3, 99.0)
        assert not srv.add_rating("x", 3, 4.0)
        assert srv.stats.rejected == 6
        assert srv.add_rating(5, 3, 4.0)          # valid still goes through
        assert float(srv.state.ratings[5, 3]) == 4.0

    def test_quarantine_ring_is_bounded(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, quarantine_capacity=5)
        for _ in range(20):
            srv.onboard_user(np.full(10, np.nan, np.float32))
        assert len(srv.quarantine.records) == 5
        assert srv.quarantine.total == 20


# ---------------------------------------------------------------------------
# Arena rotation
# ---------------------------------------------------------------------------

class TestArenaRotation:
    def test_overflow_rotates_instead_of_raising(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=1)
        srv.onboard_user(R[0])                    # fills the only slot
        uid, info = srv.onboard_user(R[1])        # used to RuntimeError
        assert uid == 21 and info["status"] == "ok"
        assert srv.stats.rotations == 1
        assert srv.n_base == 21 and srv.state.capacity == 22

    def test_flood_past_capacity(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4, c_probes=4)
        results = capacity_flood(srv, R, 14, seed=3)
        uids = [u for u, info in results]
        assert all(info["status"] == "ok" for _, info in results)
        assert uids == list(range(30, 44))         # monotonic, no gaps
        assert srv.stats.rotations == 3            # 4-slot arena, 14 users
        assert int(srv.state.n_active) == 44
        recs = srv.recommend(43, n=5)
        assert len(recs) == 5

    def test_rotation_bit_exact_data_movement(self, rng):
        """Rotated base lists must be a pure rearrangement: bitwise equal
        to a numpy re-sort of (gated base entries + recovered buffer sims)
        — no similarity arithmetic happens during rotation."""
        R = make_ratings(rng, n=25, m=12)
        srv = CFServer(R, capacity_extra=4, c_probes=4)
        for i in (3, 7, 3, 11):                    # mix of twins + fresh
            srv.onboard_user(R[i])
        st = srv.state
        n_base, n_act, extra = 25, 29, 4
        U = np.asarray(unsorted_rows(
            st.sim_vals, st.sim_idx,
            jnp.arange(n_base, n_act, dtype=jnp.int32)))
        rot = rotate_arena(st, n_base=n_base, extra=extra)
        assert rot.capacity == n_act + extra
        for x in range(n_base):
            vals = np.asarray(st.sim_vals[x])
            idx = np.asarray(st.sim_idx[x])
            keep = idx < n_base                    # pre-rotation real entries
            ref = np.sort(np.concatenate(
                [vals[keep], U[:, x].astype(vals.dtype)]))
            row = np.asarray(rot.sim_vals[x])
            np.testing.assert_array_equal(row[-ref.shape[0]:], ref)
            ridx = np.asarray(rot.sim_idx[x])
            real = row > SENTINEL_GATE
            assert set(ridx[real]) == set(range(n_act))

    def test_rotated_arena_matches_fresh_traditional_build(self, rng):
        R = make_ratings(rng, n=25, m=12)
        srv = CFServer(R, capacity_extra=5, c_probes=4)
        fresh = make_ratings(np.random.default_rng(7), n=3, m=12)
        for r in (R[3], fresh[0], R[3], fresh[1], fresh[2]):
            srv.onboard_user(r)
        srv.onboard_user(R[8])                     # triggers rotation
        assert srv.stats.rotations == 1
        n_act = int(srv.state.n_active)
        S_ref = np.asarray(cosine_matrix(srv.state.ratings[:n_act]))
        # The compacted region (everything rotated into the base) is
        # all-pairs complete and matches a fresh traditional build ...
        nb = srv.n_base
        S_rot = _unsorted_active(srv.state, n_act)
        np.testing.assert_allclose(S_rot[:nb, :nb], S_ref[:nb, :nb],
                                   atol=1e-5)
        np.testing.assert_allclose(S_rot[nb:, :nb], S_ref[nb:, :nb],
                                   atol=1e-5)      # new rows vs base
        # ... and the post-rotation onboard's deferred symmetric entries
        # land on the next compaction: rotating once more yields the full
        # fresh matrix.
        full = rotate_arena(srv.state, n_base=nb, extra=0)
        S_full = _unsorted_active(full, n_act)
        np.testing.assert_allclose(S_full, S_ref, atol=1e-5)
        # rows stay ascending and healthy after rotation
        assert bool(arena_healthy(srv.state.sim_vals, srv.state.ratings,
                                  srv.state.norms, srv.state.n_active))

    def test_rotation_gates_refreshed_rows(self, rng):
        """A base row re-sorted by add_rating already contains write-region
        entries; rotation must not duplicate them."""
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, c_probes=4)
        srv.onboard_user(R[2])
        srv.add_rating(5, 3, 4.0)                  # row 5 now sees user 20
        srv.onboard_user(R[6])                     # fills the arena
        srv.onboard_user(R[9])                     # rotates, then onboards
        assert srv.stats.rotations == 1
        idx = np.asarray(srv.state.sim_idx)
        vals = np.asarray(srv.state.sim_vals)
        n_act = int(srv.state.n_active)
        for x in range(n_act):
            real = idx[x][vals[x] > SENTINEL_GATE]
            assert len(real) == len(set(real)), f"duplicate ids in row {x}"


# ---------------------------------------------------------------------------
# Degradation ladder (latency spikes, virtual time)
# ---------------------------------------------------------------------------

class TestDegradationLadder:
    def _server(self, R, clock, **kw):
        mon = StragglerMonitor(window=20, straggler_ratio=2.0,
                               hang_timeout_s=1000.0,
                               consecutive_to_shrink=2, clock=clock)
        return CFServer(R, capacity_extra=64, c_probes=4, monitor=mon,
                        snapshot_every=10_000, check_every=10_000, **kw)

    def test_spikes_step_down_ladder_then_recover(self, rng):
        R = make_ratings(rng, n=40, m=16)
        clock = FakeClock()
        srv = self._server(R, clock, recover_after=5, shed_cooldown_s=10.0)
        inject_latency(srv, clock, [0.1] * 12 + [1.0] * 4 + [0.1] * 30)
        for i in range(16):
            _, info = srv.onboard_user(R[i % 40])
            assert info["status"] == "ok"
        # two straggler verdicts: twinsearch -> traditional -> shed (the
        # latency walk skips the replica-owned ``degraded`` rung)
        assert srv.stats.degradations == 2
        assert srv.level == LEVEL_SHED

        # shed: backpressure, no work, no raise
        uid, info = srv.onboard_user(R[0])
        assert uid == -1 and info["status"] == "shed"
        assert info["retry_after_s"] > 0
        assert srv.stats.shed == 1

        # cooldown expiry probes traditional again, healthy streak recovers
        clock.advance(11.0)
        _, info = srv.onboard_user(R[0])
        assert info["status"] == "ok" and srv.level == LEVEL_TRADITIONAL
        for i in range(6):
            srv.onboard_user(R[i])
        assert srv.level == LEVEL_TWINSEARCH
        assert srv.stats.recoveries == 2

    def test_hang_sheds_immediately(self, rng):
        R = make_ratings(rng, n=40, m=16)
        clock = FakeClock()
        mon = StragglerMonitor(window=20, straggler_ratio=2.0,
                               hang_timeout_s=5.0,
                               consecutive_to_shrink=2, clock=clock)
        srv = CFServer(R, capacity_extra=16, c_probes=4, monitor=mon,
                       snapshot_every=10_000, check_every=10_000)
        inject_latency(srv, clock, [0.1] * 10 + [60.0])
        for i in range(10):
            srv.onboard_user(R[i])
        assert srv.level == LEVEL_TWINSEARCH
        _, info = srv.onboard_user(R[10])          # hang-scale latency
        assert info["status"] == "ok"              # the call did finish...
        assert srv.level == LEVEL_SHED             # ...but ABORT -> shed


# ---------------------------------------------------------------------------
# Retry / transient executor faults
# ---------------------------------------------------------------------------

class TestRetry:
    def test_transient_fault_retries_to_success(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4,
                       retry=RetryPolicy(max_attempts=4, base_delay_s=1e-4,
                                         deadline_s=10.0,
                                         sleep=lambda s: None))
        srv._onboard = Flaky(srv._onboard, fail_times=2)
        uid, info = srv.onboard_user(R[0])
        assert uid == 30 and info["status"] == "ok"
        assert srv.stats.retries == 2

    def test_permanent_fault_is_quarantined_not_raised(self, rng):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=4,
                       retry=RetryPolicy(max_attempts=3, base_delay_s=1e-4,
                                         deadline_s=10.0,
                                         sleep=lambda s: None))
        srv._onboard = Flaky(srv._onboard, fail_times=99)
        uid, info = srv.onboard_user(R[0])
        assert uid == -1 and info["status"] == "error"
        assert srv.stats.errors == 1
        assert srv.quarantine.counts["error"] == 1
        # state untouched by the failed attempts (functional updates)
        assert int(srv.state.n_active) == 30
        srv._build_jits()                          # drop the fault wrapper
        uid, info = srv.onboard_user(R[0])
        assert uid == 30 and info["status"] == "ok"


# ---------------------------------------------------------------------------
# Snapshot / rollback (state poisoning, simulated shard loss)
# ---------------------------------------------------------------------------

class TestSnapshotRollback:
    def test_poisoned_lists_roll_back(self, rng, tmp_path):
        R = make_ratings(rng, n=30, m=12)
        srv = CFServer(R, capacity_extra=8, snapshot_every=3, check_every=1,
                       snapshot_dir=str(tmp_path))
        for i in range(4):
            srv.onboard_user(R[i])
        good_n = int(srv.state.n_active)
        assert checkpoint.all_steps(str(tmp_path))  # disk snapshots landed

        poison_state(srv, rows=[2, 17])            # bit-flip corruption
        uid, info = srv.onboard_user(R[5])
        assert uid == -1 and info["status"] == "rolled_back"
        assert srv.stats.rollbacks == 1
        assert int(srv.state.n_active) <= good_n
        assert bool(arena_healthy(srv.state.sim_vals, srv.state.ratings,
                                  srv.state.norms, srv.state.n_active))
        uid, info = srv.onboard_user(R[5])         # back in business
        assert info["status"] == "ok"
        assert len(srv.recommend(int(srv.state.n_active) - 1, n=3)) == 3

    def test_simulated_shard_loss_rolls_back(self, rng):
        R = make_ratings(rng, n=32, m=12)
        srv = CFServer(R, capacity_extra=8, snapshot_every=2, check_every=1)
        for i in range(3):
            srv.onboard_user(R[i])
        # shard 2 of 4 dies; its row-shard of the ratings arena is garbage
        lost = poison_state(srv, shard=2, n_shards=4, field="ratings")
        assert lost.shape[0] == 10                 # 40-row arena / 4
        uid, info = srv.onboard_user(R[7])
        assert uid == -1 and info["status"] == "rolled_back"
        assert srv.stats.rollbacks == 1
        uid, info = srv.onboard_user(R[7])
        assert info["status"] == "ok"

    def test_rollback_across_rotation_restores_geometry(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, snapshot_every=10_000,
                       check_every=1)
        cap0, nb0 = srv.state.capacity, srv.n_base
        for i in range(5):                         # forces rotations
            srv.onboard_user(R[i])
        assert srv.stats.rotations >= 1
        assert srv.state.capacity > cap0
        poison_state(srv, rows=[1])
        _, info = srv.onboard_user(R[6])
        assert info["status"] == "rolled_back"
        # only the construction snapshot existed: geometry rolled back too
        assert srv.state.capacity == cap0 and srv.n_base == nb0
        _, info = srv.onboard_user(R[6])
        assert info["status"] == "ok"


# ---------------------------------------------------------------------------
# Invariant-check op (verify_rows family)
# ---------------------------------------------------------------------------

class TestHealthOp:
    def test_rows_sorted_finite_matches_ref(self, rng):
        vals = np.sort(rng.normal(size=(8, 16)).astype(np.float32), axis=1)
        vals[2, 5] = np.nan                        # live + non-finite
        vals[4, 3], vals[4, 4] = vals[4, 4], vals[4, 3]   # live + unsorted
        vals[6, 0] = np.inf                        # unsorted AND non-finite
        live = np.arange(8) < 7                    # row 7 is dead
        vals[7, :] = np.nan                        # dead rows never flag
        got = np.asarray(rows_sorted_finite(jnp.asarray(vals), jnp.int32(7)))
        ref = np.asarray(rows_sorted_finite_ref(jnp.asarray(vals),
                                                jnp.asarray(live)))
        np.testing.assert_array_equal(got, ref)
        np.testing.assert_array_equal(
            got, [True, True, False, True, False, True, False, True])

    def test_arena_healthy_gates(self, rng):
        R = make_ratings(rng, n=16, m=8)
        srv = CFServer(R, capacity_extra=2)
        st = srv.state
        ok = lambda s: bool(arena_healthy(s.sim_vals, s.ratings, s.norms,
                                          s.n_active))
        assert ok(st)
        assert not ok(st._replace(
            norms=st.norms.at[3].set(jnp.float32(jnp.nan))))
        assert not ok(st._replace(n_active=jnp.int32(99)))
        bad = st.sim_vals.at[0, 0].set(jnp.float32(5.0))   # > all: unsorted
        assert not ok(st._replace(sim_vals=bad))


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------

class TestSatellites:
    def test_onboard_ms_is_bounded_ring(self):
        stats = ServerStats(latency_window=8)
        for i in range(100):
            stats.onboard_ms.append(float(i))
        assert len(stats.onboard_ms) == 8
        s = stats.summary()
        # percentiles over the trailing window only (92..99)
        assert s["onboard_p50_ms"] == 96.0
        assert s["onboard_p99_ms"] == 99.0

    def test_straggler_finish_without_start(self):
        mon = StragglerMonitor()
        assert mon.step_finished() is Action.CONTINUE
        assert mon.stats() == {}                   # no sample recorded
        mon.step_started()
        assert mon.step_finished() is Action.CONTINUE
        assert mon.step_finished() is Action.CONTINUE   # double-finish too
        assert mon.stats()["n"] == 1

    def test_add_rating_jit_hoisted(self, rng):
        R = make_ratings(rng, n=12, m=8)
        srv = CFServer(R, capacity_extra=2)
        # jits exist before any call — a first-call failure can't leave the
        # server half-initialised
        for attr in ("_add", "_init_cache", "_onboard", "_onboard_trad",
                     "_recommend", "_predict"):
            assert hasattr(srv, attr), attr
        assert srv._cache is None                  # cache itself stays lazy


# ---------------------------------------------------------------------------
# Write-ahead log (unit)
# ---------------------------------------------------------------------------

class TestWriteAheadLog:
    def test_roundtrip_bit_exact(self, tmp_path, rng):
        wal = WriteAheadLog(str(tmp_path))
        r = rng.normal(size=(16,)).astype(np.float32)
        p = rng.integers(0, 40, size=4).astype(np.int32)
        wal.append(1, "onboard", {"use_twin": True},
                   {"ratings": r, "probes": p})
        wal.append(2, "add_rating", {"user": 3, "item": 5, "rating": 4.0})
        wal.append(3, "rotate")
        wal.close()

        wal2 = WriteAheadLog(str(tmp_path))        # reopen
        recs = wal2.records()
        assert [x.seq for x in recs] == [1, 2, 3]
        assert [x.op for x in recs] == ["onboard", "add_rating", "rotate"]
        np.testing.assert_array_equal(recs[0].arrays["ratings"], r)
        np.testing.assert_array_equal(recs[0].arrays["probes"], p)
        assert recs[0].fields == {"use_twin": True}
        assert recs[1].fields["rating"] == 4.0

    def test_torn_tail_is_repaired(self, tmp_path, rng):
        wal = WriteAheadLog(str(tmp_path))
        for s in range(1, 4):
            wal.append(s, "add_rating", {"user": s, "item": 0,
                                         "rating": 1.0})
        wal.close()
        # tear the tail mid-record, as a crash mid-append would
        with open(wal.path, "r+b") as f:
            f.truncate(wal.size_bytes() - 7)
        wal2 = WriteAheadLog(str(tmp_path))
        assert [x.seq for x in wal2.records()] == [1, 2]
        wal2.append(3, "rotate")                   # appendable after repair
        assert [x.seq for x in wal2.records()] == [1, 2, 3]

    def test_truncation_policies(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path))
        for s in range(1, 6):
            wal.append(s, "rotate")
        wal.truncate_through(3)                    # durable snapshot at 3
        assert [x.seq for x in wal.records()] == [4, 5]
        wal.truncate_after(4)                      # rollback to 4
        assert [x.seq for x in wal.records()] == [4]
        assert wal.truncations == 2

    def test_aborted_ops_are_filtered(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path))
        wal.append(1, "onboard", {"use_twin": False})
        wal.append(2, "onboard", {"use_twin": False})
        wal.append(3, "abort", {"target": 2})      # op 2 failed after log
        wal.append(4, "rotate")
        assert [x.seq for x in wal.records()] == [1, 4]

    def test_fsync_off_still_readable(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path), fsync=False)
        wal.append(1, "rotate")
        assert len(WriteAheadLog(str(tmp_path)).records()) == 1

    def test_raw_bounds_include_aborts(self, tmp_path):
        """first_seq/last_seq are raw bounds: aborted ops and their
        compensation records consumed sequence numbers even though
        records() filters them from the replay stream."""
        wal = WriteAheadLog(str(tmp_path))
        assert (wal.first_seq, wal.last_seq) == (0, 0)
        wal.append(1, "onboard", {"use_twin": False})
        wal.append(2, "abort", {"target": 1})
        assert (wal.first_seq, wal.last_seq) == (1, 2)
        wal.close()
        wal2 = WriteAheadLog(str(tmp_path))        # bounds survive reopen
        assert (wal2.first_seq, wal2.last_seq) == (1, 2)
        assert wal2.records() == []                # yet nothing replays

    def test_truncate_after_rewinds_last_seq(self, tmp_path):
        """Rollback truncation rewinds last_seq to the rollback point so
        discarded seqs are reissued — even when every record is dropped."""
        wal = WriteAheadLog(str(tmp_path))
        for s in range(1, 6):
            wal.append(s, "rotate")
        wal.truncate_after(2)
        assert wal.last_seq == 2
        wal.truncate_after(0)                      # drops every record
        assert (wal.first_seq, wal.last_seq) == (0, 0)

    def test_truncate_through_keeps_last_seq(self, tmp_path):
        """Checkpoint truncation un-consumes nothing: last_seq holds even
        when the log empties, so numbering never restarts over old seqs."""
        wal = WriteAheadLog(str(tmp_path))
        for s in range(1, 4):
            wal.append(s, "rotate")
        wal.truncate_through(3)                    # empties the log
        assert (wal.first_seq, wal.last_seq) == (0, 3)
        wal.append(4, "rotate")
        assert (wal.first_seq, wal.last_seq) == (4, 4)


# ---------------------------------------------------------------------------
# Checkpoint CRC (satellite)
# ---------------------------------------------------------------------------

class TestCheckpointCRC:
    def _tree(self, rng, shift=0.0):
        return {"a": jnp.asarray(rng.normal(size=(8, 8)) + shift,
                                 jnp.float32),
                "b": jnp.asarray(np.arange(16), jnp.int32)}

    def _corrupt_leaf(self, ckpt_dir, step, fname="a.npy"):
        path = os.path.join(ckpt_dir, f"step_{step:010d}", fname)
        with open(path, "r+b") as f:
            f.seek(-4, os.SEEK_END)                # flip data bytes, keep
            f.write(b"\xde\xad\xbe\xef")           # the .npy header valid

    def test_corrupt_leaf_falls_back_to_previous_step(self, tmp_path, rng):
        d = str(tmp_path)
        t1 = self._tree(rng)
        t2 = self._tree(rng, shift=1.0)
        checkpoint.save(d, 1, t1)
        checkpoint.save(d, 2, t2)
        self._corrupt_leaf(d, 2)
        tree, step, _ = checkpoint.restore(d, t1)
        assert step == 1                           # newest was corrupt
        np.testing.assert_array_equal(np.asarray(tree["a"]),
                                      np.asarray(t1["a"]))

    def test_explicit_step_raises_on_corruption(self, tmp_path, rng):
        d = str(tmp_path)
        t = self._tree(rng)
        checkpoint.save(d, 1, t)
        self._corrupt_leaf(d, 1)
        with pytest.raises(checkpoint.CorruptCheckpointError):
            checkpoint.restore(d, t, step=1)

    def test_all_corrupt_raises(self, tmp_path, rng):
        d = str(tmp_path)
        t = self._tree(rng)
        checkpoint.save(d, 1, t)
        checkpoint.save(d, 2, t)
        self._corrupt_leaf(d, 1)
        self._corrupt_leaf(d, 2)
        with pytest.raises(checkpoint.CorruptCheckpointError):
            checkpoint.restore(d, t)

    def test_missing_leaf_file_is_corruption(self, tmp_path, rng):
        d = str(tmp_path)
        t = self._tree(rng)
        checkpoint.save(d, 1, t)
        checkpoint.save(d, 2, t)
        os.remove(os.path.join(d, "step_0000000002", "a.npy"))
        _, step, _ = checkpoint.restore(d, t)
        assert step == 1


# ---------------------------------------------------------------------------
# Crash + WAL recovery (tentpole): kill-and-restart is bit-exact
# ---------------------------------------------------------------------------

def _assert_states_equal(a, b):
    for f in ("ratings", "norms", "sim_vals", "sim_idx"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"field {f} diverged")
    assert int(a.n_active) == int(b.n_active)


class TestCrashRecovery:
    KNOBS = dict(capacity_extra=6, c_probes=4, snapshot_every=5,
                 check_every=3)

    def _server(self, R, tmp_path, tag, **extra):
        return CFServer(R, wal_dir=str(tmp_path / f"{tag}-wal"),
                        snapshot_dir=str(tmp_path / f"{tag}-snap"),
                        **{**self.KNOBS, **extra})

    def _pool(self, rng, R):
        fresh = make_ratings(np.random.default_rng(101), n=6, m=R.shape[1])
        # mix of twins (base copies) and fresh rows: both onboard paths
        return np.concatenate([R[:3], fresh, R[5:8]], axis=0)

    @pytest.mark.parametrize("point,nth", [
        ("onboard.pre_wal", 4),
        ("onboard.post_wal", 4),
        ("onboard.post_commit", 4),
        ("rotate.post_wal", 1),                 # fires at the 7th onboard
    ])
    def test_kill_and_restart_bit_exact(self, rng, tmp_path, point, nth):
        """A crash at any injected crash point mid-sequence, recovered via
        checkpoint + WAL replay, converges to the exact same arena as an
        uncrashed run over the same request sequence."""
        R = make_ratings(rng, n=40, m=16)
        pool = self._pool(rng, R)
        n_ops = 10                              # > capacity_extra: rotates

        oracle = self._server(R, tmp_path, "oracle")
        for i in range(n_ops):
            _, info = oracle.onboard_user(pool[i % len(pool)])
            assert info["status"] == "ok"

        victim = self._server(R, tmp_path, "victim")
        install_crash(victim, point, nth=nth)
        crashed = False
        for i in range(n_ops):
            try:
                victim.onboard_user(pool[i % len(pool)])
            except SimulatedCrash as e:
                assert e.point == point
                crashed = True
                break
        assert crashed, f"crash point {point} never fired"

        recovered = CFServer.recover(
            R, wal_dir=str(tmp_path / "victim-wal"),
            snapshot_dir=str(tmp_path / "victim-snap"), **self.KNOBS)
        # ops already applied (WAL-replayed or checkpointed) must not be
        # re-issued; everything else is, as a client retry would
        applied = int(recovered.state.n_active) - 40
        for i in range(applied, n_ops):
            _, info = recovered.onboard_user(pool[i % len(pool)])
            assert info["status"] == "ok"

        _assert_states_equal(recovered.state, oracle.state)
        assert recovered.n_base == oracle.n_base
        assert recovered.state.capacity == oracle.state.capacity
        # and the recovered server keeps serving identically
        assert recovered.recommend(5, n=5) == oracle.recommend(5, n=5)

    @pytest.mark.parametrize("point,applied", [
        ("add_rating.pre_wal", False),          # op lost: not yet logged
        ("add_rating.post_wal", True),          # logged: replay applies it
        ("add_rating.post_commit", True),
    ])
    def test_crash_around_add_rating(self, rng, tmp_path, point, applied):
        R = make_ratings(rng, n=30, m=12)
        oracle = self._server(R, tmp_path, "oracle")
        for i in range(3):
            oracle.onboard_user(R[i])
        if applied:
            assert oracle.add_rating(2, 3, 4.0)

        victim = self._server(R, tmp_path, "victim")
        for i in range(3):
            victim.onboard_user(R[i])
        install_crash(victim, point)
        with pytest.raises(SimulatedCrash):
            victim.add_rating(2, 3, 4.0)

        recovered = CFServer.recover(
            R, wal_dir=str(tmp_path / "victim-wal"),
            snapshot_dir=str(tmp_path / "victim-snap"), **self.KNOBS)
        _assert_states_equal(recovered.state, oracle.state)

    def test_recovery_with_wal_only(self, rng, tmp_path):
        """No disk checkpoints at all: replay runs over a fresh build of
        the same base ratings and still lands bit-exact."""
        R = make_ratings(rng, n=30, m=12)
        knobs = dict(capacity_extra=6, c_probes=4,
                     wal_dir=str(tmp_path / "wal"))
        srv = CFServer(R, **knobs)
        for i in range(8):                      # crosses one rotation
            srv.onboard_user(R[i])
        srv.add_rating(1, 2, 3.0)
        ref = srv.state

        recovered = CFServer.recover(R, **knobs)
        assert recovered.stats.wal_replayed == len(srv.wal.records())
        _assert_states_equal(recovered.state, ref)

    def test_aborted_onboard_not_replayed(self, rng, tmp_path):
        """An onboard that failed after its WAL append leaves an abort
        record; recovery must skip it."""
        R = make_ratings(rng, n=30, m=12)
        srv = self._server(R, tmp_path, "victim",
                           retry=RetryPolicy(max_attempts=2,
                                             base_delay_s=1e-4,
                                             deadline_s=10.0,
                                             sleep=lambda s: None))
        srv.onboard_user(R[0])
        srv._onboard = Flaky(srv._onboard, fail_times=99)
        _, info = srv.onboard_user(R[1])
        assert info["status"] == "error"
        srv._build_jits()                       # drop the fault wrapper
        srv.onboard_user(R[2])
        ref = srv.state

        recovered = CFServer.recover(
            R, wal_dir=str(tmp_path / "victim-wal"),
            snapshot_dir=str(tmp_path / "victim-snap"), **self.KNOBS)
        _assert_states_equal(recovered.state, ref)

    _FAST_RETRY = dict(max_attempts=2, base_delay_s=1e-4, deadline_s=10.0,
                       sleep=lambda s: None)

    def test_aborted_tail_never_reuses_seqs(self, rng, tmp_path):
        """Crash right after an onboard aborts: the WAL tail is the abort
        record.  Recovery must resume numbering past it — reissuing the
        aborted seq would make records() drop the next committed op as
        aborted on a later recovery, silently losing an acked mutation."""
        R = make_ratings(rng, n=30, m=12)
        srv = self._server(R, tmp_path, "victim",
                           retry=RetryPolicy(**self._FAST_RETRY))
        srv.onboard_user(R[0])
        srv._onboard = Flaky(srv._onboard, fail_times=99)
        _, info = srv.onboard_user(R[1])
        assert info["status"] == "error"            # WAL tail = abort

        r1 = CFServer.recover(
            R, wal_dir=str(tmp_path / "victim-wal"),
            snapshot_dir=str(tmp_path / "victim-snap"), **self.KNOBS)
        assert r1._seq >= r1.wal.last_seq           # numbering moved past it
        _, info = r1.onboard_user(R[2])             # committed + acked
        assert info["status"] == "ok"
        ref = r1.state

        r2 = CFServer.recover(                      # second kill-and-restart
            R, wal_dir=str(tmp_path / "victim-wal"),
            snapshot_dir=str(tmp_path / "victim-snap"), **self.KNOBS)
        _assert_states_equal(r2.state, ref)

    def test_wal_only_recovery_with_aborted_first_op(self, rng, tmp_path):
        """No checkpoints + the first logged op aborted: recovery must not
        mistake the abort-filtered prefix for a truncated one."""
        R = make_ratings(rng, n=30, m=12)
        knobs = dict(capacity_extra=6, c_probes=4,
                     wal_dir=str(tmp_path / "wal"))
        srv = CFServer(R, retry=RetryPolicy(**self._FAST_RETRY), **knobs)
        srv._onboard = Flaky(srv._onboard, fail_times=99)
        _, info = srv.onboard_user(R[0])
        assert info["status"] == "error"            # seq 1 aborted
        srv._build_jits()                           # drop the fault wrapper
        srv.onboard_user(R[1])
        ref = srv.state

        recovered = CFServer.recover(R, **knobs)    # must not raise
        _assert_states_equal(recovered.state, ref)

    @pytest.mark.parametrize("snapshot_every,wal_empty", [
        (2, True),      # WAL truncated through the corrupt newest step
        (4, False),     # WAL holds a suffix, but past the gap
    ])
    def test_fallback_over_truncated_wal_fails_loudly(self, rng, tmp_path,
                                                      snapshot_every,
                                                      wal_empty):
        """Newest checkpoint corrupt after the WAL was truncated through
        it: the ops between the fallback step and the corrupt one are
        unrecoverable, and recovery must raise instead of silently
        replaying over the gap (JAX's clamped indexing would corrupt rows
        without a trace)."""
        R = make_ratings(rng, n=30, m=12)
        srv = self._server(R, tmp_path, "victim",
                           snapshot_every=snapshot_every)
        for i in range(6):
            _, info = srv.onboard_user(R[i])
            assert info["status"] == "ok"
        assert (len(srv.wal.records()) == 0) == wal_empty

        snap = tmp_path / "victim-snap"
        steps = checkpoint.all_steps(str(snap))
        assert len(steps) >= 2
        step_dir = snap / f"step_{steps[-1]:010d}"
        leaf = next(p for p in sorted(step_dir.iterdir())
                    if p.suffix == ".npy")
        with open(leaf, "r+b") as f:                # flip data bytes, keep
            f.seek(-4, os.SEEK_END)                 # the .npy header valid
            f.write(b"\xde\xad\xbe\xef")

        with pytest.raises(RuntimeError, match="gap|truncated"):
            CFServer.recover(
                R, wal_dir=str(tmp_path / "victim-wal"),
                snapshot_dir=str(tmp_path / "victim-snap"),
                **{**self.KNOBS, "snapshot_every": snapshot_every})

    def test_recovery_converges_after_repeated_crashes(self, rng, tmp_path):
        """Crash -> recover -> crash again during recovery-adjacent ops:
        the WAL + checkpoint pair is idempotent."""
        R = make_ratings(rng, n=30, m=12)
        srv = self._server(R, tmp_path, "victim")
        for i in range(4):
            srv.onboard_user(R[i])
        for _ in range(3):                      # repeated kill-and-restart
            srv = CFServer.recover(
                R, wal_dir=str(tmp_path / "victim-wal"),
                snapshot_dir=str(tmp_path / "victim-snap"), **self.KNOBS)
        oracle = self._server(R, tmp_path, "oracle")
        for i in range(4):
            oracle.onboard_user(R[i])
        _assert_states_equal(srv.state, oracle.state)


# ---------------------------------------------------------------------------
# Replication: replica kill, failover reads, re-replication (tentpole)
# ---------------------------------------------------------------------------

class TestReplication:
    def test_placement_chained_declustering(self):
        cfg = ReplicationConfig(n_shards=4, r=2)
        assert cfg.owners(0) == (0, 1)
        assert cfg.owners(3) == (3, 0)
        # any single node loss leaves every shard a survivor
        for node in range(4):
            for s in range(4):
                assert any(n != node for n in cfg.owners(s))

    @pytest.mark.parametrize("node", [0, 1, 2, 3])
    def test_any_single_replica_down_stays_available(self, rng, node):
        """Acceptance: with any single node down (its replicas gone AND
        its primary shard rows garbage) the server answers identically,
        heals from survivors, and restores r-way redundancy — all without
        a single similarity-kernel call."""
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, c_probes=4,
                       replication=ReplicationConfig(n_shards=4, r=2))
        for i in range(4):
            srv.onboard_user(R[i])
        users = [1, 11, 21, 31, 41]
        before = {u: srv.recommend(u, n=5) for u in users}

        forbid_similarity_kernels(srv)          # recovery = data movement
        kill_replica(srv, node)
        assert srv.replicas.degraded()

        after = {u: srv.recommend(u, n=5) for u in users}
        assert after == before                  # correct top-n, no raise
        assert srv.stats.repairs >= 1           # healed, not rolled back
        assert srv.stats.rollbacks == 0
        assert srv.replicas.redundancy() == 2   # re-replication completed
        assert srv.replicas.rebuilt_rows > 0

    def test_degraded_rung_pins_ladder_until_redundancy_restored(self, rng):
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, c_probes=4, recover_after=1,
                       replication=ReplicationConfig(n_shards=4, r=2,
                                                     rebuild_rows=5))
        srv.onboard_user(R[0])
        assert srv.level == LEVEL_TWINSEARCH
        srv.replicas.kill_node(2)               # replicas only; primary ok
        _, info = srv.onboard_user(R[1])
        assert info["status"] == "ok"
        assert srv.level == LEVEL_DEGRADED      # rung entered
        assert info["level"] == "degraded"
        assert not info["twin_found"]           # degraded = traditional path

        # budgeted rebuild: a few ticks to copy 2 replicas x 12 rows
        seen_degraded = 0
        for _ in range(8):
            srv.recommend(1, n=3)
            if srv.replicas.degraded():
                seen_degraded += 1
        assert seen_degraded >= 2               # budget made it incremental
        assert srv.replicas.redundancy() == 2
        assert srv.level == LEVEL_TRADITIONAL   # rung released on restore
        _, info = srv.onboard_user(R[2])        # healthy streak recovers
        assert srv.level == LEVEL_TWINSEARCH

    def test_unrecoverable_rows_fall_back_to_rollback(self, rng):
        """r=1 (no redundancy): losing the only replica of a shard leaves
        poison unrecoverable — the PR 2 rollback remains the backstop and
        the server stays pinned degraded but available."""
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, c_probes=4, check_every=1,
                       replication=ReplicationConfig(n_shards=4, r=1))
        srv.onboard_user(R[0])
        kill_replica(srv, 2)                    # poisons primary shard 2
        _, info = srv.onboard_user(R[1])
        assert info["status"] == "rolled_back"
        assert srv.stats.rollbacks == 1
        assert srv.level == LEVEL_DEGRADED      # dead replica never revives
        _, info = srv.onboard_user(R[1])
        assert info["status"] == "ok"           # still serving

    def test_rebuilding_replica_absorbs_writes(self, rng):
        """Writes landing mid-rebuild must not be lost: rows already
        copied take them directly, later rows pick them up from the
        (already-updated) source replica."""
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, c_probes=4,
                       replication=ReplicationConfig(n_shards=4, r=2,
                                                     rebuild_rows=3))
        srv.replicas.kill_node(1)
        while srv.replicas.degraded():
            srv.add_rating(int(rng.integers(0, 40)),
                           int(rng.integers(0, 16)), 4.0)
        # every replica copy now mirrors the primary bit-exactly
        for (n, s), rep in srv.replicas._replicas.items():
            assert rep.state is ReplicaState.HEALTHY
            sl = srv.replicas._slices[s]
            for f in ("ratings", "norms", "sim_vals", "sim_idx"):
                np.testing.assert_array_equal(
                    rep.data[f], np.asarray(getattr(srv.state, f))[sl],
                    err_msg=f"replica ({n},{s}) field {f}")

    def test_replica_sweep_catches_silent_corruption(self, rng):
        R = make_ratings(rng, n=40, m=16)
        srv = CFServer(R, capacity_extra=8, check_every=2,
                       replication=ReplicationConfig(n_shards=4, r=2))
        rep = srv.replicas._replicas[(1, 1)]
        rep.data["sim_vals"][0, 0] = np.nan     # silent replica bit-flip
        for i in range(3):                      # check_every sweeps it
            srv.onboard_user(R[i])
        assert srv.replicas._replicas[(1, 1)].state is not \
            ReplicaState.HEALTHY or srv.replicas.rebuilt_rows > 0
        assert srv.replicas.dead_marks >= 1

    def test_rotation_resets_replicas_to_new_geometry(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=4, c_probes=4,
                       replication=ReplicationConfig(n_shards=4, r=2))
        for i in range(6):                      # forces a rotation
            srv.onboard_user(R[i])
        assert srv.stats.rotations >= 1
        assert srv.replicas.n_rows == srv.state.capacity
        # replicas mirror the rotated arena; a kill is still recoverable
        kill_replica(srv, 0)
        assert srv.recommend(3, n=3)
        assert srv.stats.rollbacks == 0


# ---------------------------------------------------------------------------
# Rotation hysteresis (satellite)
# ---------------------------------------------------------------------------

class TestRotationHysteresis:
    def test_headroom_grows_write_region(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=4, c_probes=4, rotate_headroom=2.0)
        for i in range(5):
            srv.onboard_user(R[i])
        assert srv.stats.rotations == 1
        # absorbed burst k=4, headroom 2.0 -> fresh write region 8, not 4
        assert srv.k_cap == 8
        assert srv.state.capacity == 24 + 8

    def test_headroom_reduces_rotation_count(self, rng):
        R = make_ratings(rng, n=20, m=10)
        flat = CFServer(R, capacity_extra=4, c_probes=4)
        grow = CFServer(R, capacity_extra=4, c_probes=4,
                        rotate_headroom=2.0)
        for i in range(20):
            flat.onboard_user(R[i % 20])
            grow.onboard_user(R[i % 20])
        assert grow.stats.rotations < flat.stats.rotations

    def test_rotation_duration_lands_in_stats(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, capacity_extra=2, c_probes=4)
        for i in range(5):
            srv.onboard_user(R[i])
        assert srv.stats.rotations >= 1
        assert len(srv.stats.rotation_ms) == srv.stats.rotations
        s = srv.stats.summary()
        assert s["rotation_max_ms"] > 0.0
        assert "rotation_p50_ms" in s


# ---------------------------------------------------------------------------
# Incremental (chunked, resumable) rotation — ISSUE 9 tentpole
# ---------------------------------------------------------------------------

class TestIncrementalRotation:
    def _flooded(self, rng, *, n=24, m=12, onboards=4):
        """A server whose write region holds ``onboards`` burst rows."""
        R = make_ratings(rng, n=n, m=m)
        srv = CFServer(R, ServerConfig(capacity_extra=8, c_probes=4))
        for i in range(onboards):
            assert srv.onboard_user(R[i]).ok
        return R, srv

    def test_frozen_equals_classic_when_boundary_is_live(self, rng):
        """``rotate_arena`` delegates to ``rotate_arena_frozen`` with
        n_frozen = n_active — same result, explicitly."""
        _, srv = self._flooded(rng)
        a = rotate_arena(srv.state, n_base=srv.n_base, extra=5)
        b = rotate_arena_frozen(srv.state, n_base=srv.n_base,
                                n_frozen=int(srv.state.n_active), extra=5)
        _assert_states_equal(a, b)

    def test_plan_matches_one_shot(self, rng):
        """Chunked precompute + finalize is bit-identical to the one-shot
        frozen rotation, for every chunking."""
        _, srv = self._flooded(rng)
        st = srv.state
        ref = rotate_arena_frozen(st, n_base=srv.n_base,
                                  n_frozen=int(st.n_active), extra=5)
        for chunk in (1, 3, 7, 64):
            plan = RotationPlan(st, n_base=srv.n_base, extra=5,
                                chunk_rows=chunk)
            steps = 0
            while not plan.done:
                assert plan.step(st, 2) > 0
                steps += 1
            if chunk < srv.n_base:
                assert steps > 1                  # genuinely incremental
            _assert_states_equal(plan.finalize(st), ref)

    def test_plan_matches_one_shot_under_mutation(self, rng):
        """Mid-plan mutations — carried onboards past the frozen boundary,
        a refreshed base row (dirty re-merge), a refreshed *burst* row
        (stale block, restart) — all reconcile: finalize is bit-identical
        to the one-shot frozen rotation of the final live state."""
        R, srv = self._flooded(rng)
        n_base = srv.n_base
        plan = RotationPlan(srv.state, n_base=n_base, extra=6, chunk_rows=4)
        n_frozen = plan.n_frozen
        plan.step(srv.state, 8)                   # partial precompute

        # Carried rows: onboards landing after the boundary froze.
        assert srv.onboard_user(R[10]).ok
        assert srv.onboard_user(R[11]).ok
        # Dirty base row: add_rating re-sorts row 2's list.
        assert srv.add_rating(2, 1, 5.0)
        plan.note_write(2)
        plan.step(srv.state, 8)
        # Stale burst block: a frozen burst row is refreshed -> restart.
        assert srv.add_rating(n_base + 1, 2, 3.0)
        plan.note_write(n_base + 1)
        assert plan.restarts == 1

        out = plan.finalize(srv.state)
        ref = rotate_arena_frozen(srv.state, n_base=n_base,
                                  n_frozen=n_frozen, extra=6)
        _assert_states_equal(out, ref)
        # Carried rows kept their write-region position and the arena
        # stayed open: n_active unchanged, new write region appended.
        assert int(out.n_active) == int(srv.state.n_active)
        assert out.capacity == int(srv.state.n_active) + 6

    def test_incremental_flood_matches_synchronous(self, rng):
        """The double-flood oracle, incremental edition: a server rotating
        in budget_rows slices and a synchronously-rotating server end a
        pure onboard flood with bit-identical materialized similarity
        blocks (geometry may differ — content must not)."""
        R = make_ratings(rng, n=24, m=12)
        fresh = make_ratings(np.random.default_rng(77), n=6, m=12)
        pool = np.concatenate([R[:4], fresh, R[8:12]], axis=0)

        sync = CFServer(R, ServerConfig(capacity_extra=4, c_probes=4))
        inc = CFServer(R, ServerConfig(
            capacity_extra=4, c_probes=4,
            rotation=RotationConfig(budget_rows=6)))
        for i in range(12):
            assert sync.onboard_user(pool[i % len(pool)]).ok
            assert inc.onboard_user(pool[i % len(pool)]).ok
        assert inc.stats.rotations >= 1

        def materialized(srv):
            st = rotate_arena(srv.state, n_base=srv.n_base, extra=0)
            n = int(st.n_active)
            return (_unsorted_active(st, n),
                    np.asarray(st.ratings[:n]))
        u_sync, r_sync = materialized(sync)
        u_inc, r_inc = materialized(inc)
        np.testing.assert_array_equal(r_sync, r_inc)
        np.testing.assert_array_equal(u_sync, u_inc)

    def test_step_maintenance_drains_between_bursts(self, rng):
        """Quiet-period ticks finish the rotation so no onboard ever pays
        a forced drain."""
        R = make_ratings(rng, n=24, m=12)
        srv = CFServer(R, ServerConfig(
            capacity_extra=6, c_probes=4,
            rotation=RotationConfig(budget_rows=4, reserve_slots=3)))
        for i in range(4):                         # free slots: 6 -> 2
            assert srv.onboard_user(R[i]).ok
        # the plan is in flight now; drain it during the quiet period
        ticks = 0
        while True:
            prog = srv.step_maintenance()
            ticks += 1
            if not prog["active"]:
                break
            assert ticks < 100
        assert srv.stats.rotations == 1
        assert srv.stats.forced_drains == 0
        assert prog["free_slots"] > 2              # swap re-opened the arena
        # and the pause the swap charged is recorded separately from the
        # total rotation work
        assert len(srv.stats.rotation_pause_ms) == 1
        assert srv.stats.summary()["rotation_pause_max_ms"] > 0.0

    def test_rotation_ms_still_tracks_rotations(self, rng):
        R = make_ratings(rng, n=20, m=10)
        srv = CFServer(R, ServerConfig(
            capacity_extra=4, c_probes=4,
            rotation=RotationConfig(budget_rows=4)))
        for i in range(14):
            assert srv.onboard_user(R[i % 20]).ok
        assert srv.stats.rotations >= 1
        assert len(srv.stats.rotation_ms) == srv.stats.rotations
        assert len(srv.stats.rotation_pause_ms) == srv.stats.rotations


class TestIncrementalRotationCrash:
    """Crash mid-partial-rotation: recovery lands bit-exact at every
    injected point.  The invariants are sharp per point — a pure
    precompute slice logs nothing, a logged-but-unapplied swap replays
    via ``rotate_arena_frozen``, an applied swap recovers as-is."""

    def _config(self, tmp_path, tag):
        return ServerConfig(
            capacity_extra=6, c_probes=4,
            snapshot=SnapshotConfig(every=100, check_every=100,
                                    dir=str(tmp_path / f"{tag}-snap")),
            wal=WalConfig(dir=str(tmp_path / f"{tag}-wal")),
            rotation=RotationConfig(budget_rows=2))

    def _crash_run(self, R, tmp_path, point):
        cfg = self._config(tmp_path, "victim")
        victim = CFServer(R, cfg)
        install_crash(victim, point, nth=1)
        crashed = False
        for i in range(10):
            try:
                victim.onboard_user(R[i])
            except SimulatedCrash as e:
                assert e.point == point
                crashed = True
                break
        assert crashed, f"crash point {point} never fired"
        return cfg, victim

    def test_crash_on_precompute_step_loses_nothing(self, rng, tmp_path):
        """``rotation.step`` logs nothing — recovery must equal the
        victim's live state at the crash, bit for bit."""
        R = make_ratings(rng, n=30, m=12)
        cfg, victim = self._crash_run(R, tmp_path, "rotation.step")
        recovered = CFServer.recover(R, cfg)
        _assert_states_equal(recovered.state, victim.state)
        assert recovered.n_base == victim.n_base

    def test_crash_after_commit_record_replays_the_swap(self, rng,
                                                        tmp_path):
        """``rotation.commit_post_wal``: the swap is logged but not
        applied.  Recovery must replay it — bit-identical to the frozen
        rotation of the victim's (pre-swap) live state."""
        R = make_ratings(rng, n=30, m=12)
        cfg, victim = self._crash_run(R, tmp_path,
                                      "rotation.commit_post_wal")
        plan = victim._plan
        assert plan is not None and plan.done
        expected = rotate_arena_frozen(victim.state, n_base=plan.n_base,
                                       n_frozen=plan.n_frozen,
                                       extra=plan.extra)
        recovered = CFServer.recover(R, cfg)
        _assert_states_equal(recovered.state, expected)
        assert recovered.n_base == plan.n_frozen
        assert recovered.stats.rotations == 1

    def test_crash_after_swap_recovers_the_swap(self, rng, tmp_path):
        """``rotation.post_swap``: swap logged and applied — recovery
        equals the victim's post-swap state."""
        R = make_ratings(rng, n=30, m=12)
        cfg, victim = self._crash_run(R, tmp_path, "rotation.post_swap")
        recovered = CFServer.recover(R, cfg)
        _assert_states_equal(recovered.state, victim.state)
        assert recovered.n_base == victim.n_base
        assert recovered.state.capacity == victim.state.capacity

    @pytest.mark.parametrize("point", ROTATION_CRASH_POINTS)
    def test_recovered_run_converges_with_uncrashed(self, rng, tmp_path,
                                                    point):
        """After recovery, re-issuing the unapplied requests converges to
        the same arena as an uncrashed incremental run."""
        R = make_ratings(rng, n=30, m=12)
        n_ops = 10
        oracle = CFServer(R, self._config(tmp_path, "oracle"))
        for i in range(n_ops):
            assert oracle.onboard_user(R[i]).ok

        cfg, victim = self._crash_run(R, tmp_path, point)
        recovered = CFServer.recover(R, cfg)
        applied = int(recovered.state.n_active) - 30
        for i in range(applied, n_ops):
            assert recovered.onboard_user(R[i]).ok
        _assert_states_equal(recovered.state, oracle.state)
        assert recovered.n_base == oracle.n_base


# ---------------------------------------------------------------------------
# WAL group commit + batched replay — ISSUE 9 tentpole
# ---------------------------------------------------------------------------

class TestWalGroupCommit:
    def _rec(self, i):
        return dict(fields={"i": i},
                    arrays={"x": np.full(4, i, np.float32)})

    def test_batch_coalesces_into_one_sync(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "w"))
        with wal.batch():
            for i in range(5):
                wal.append(i + 1, "onboard", **self._rec(i))
            assert wal.syncs == 0            # nothing flushed mid-batch
        assert wal.syncs == 1                # one write+fsync for all 5
        assert [r.seq for r in wal.records()] == [1, 2, 3, 4, 5]
        assert wal.appended == 5 and len(wal) == 5

    def test_unbatched_appends_sync_each(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "w"))
        for i in range(5):
            wal.append(i + 1, "onboard", **self._rec(i))
        assert wal.syncs == 5

    def test_batched_records_survive_reopen(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "w"))
        with wal.batch():
            for i in range(3):
                wal.append(i + 1, "onboard", **self._rec(i))
        wal.close()
        w2 = WriteAheadLog(str(tmp_path / "w"))
        recs = w2.records()
        assert [r.seq for r in recs] == [1, 2, 3]
        np.testing.assert_array_equal(recs[2].arrays["x"],
                                      np.full(4, 2, np.float32))

    def test_reads_and_truncation_flush_pending(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "w"))
        with wal.batch():
            wal.append(1, "onboard", **self._rec(1))
            # a read inside the batch must see the buffered record
            assert [r.seq for r in wal.records()] == [1]
            assert wal.syncs == 1
            wal.append(2, "onboard", **self._rec(2))
            wal.truncate_after(1)            # flushes, then rewrites
            assert len(wal) == 1 and wal.last_seq == 1
        assert [r.seq for r in wal.records()] == [1]

    def test_nested_batches_flush_once_at_outermost(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "w"))
        with wal.batch():
            wal.append(1, "onboard", **self._rec(1))
            with wal.batch():
                wal.append(2, "onboard", **self._rec(2))
            assert wal.syncs == 0            # inner exit does not flush
        assert wal.syncs == 1

    def test_onboard_batch_one_fsync_and_bit_exact_recovery(self, rng,
                                                            tmp_path):
        R = make_ratings(rng, n=24, m=12)
        cfg = ServerConfig(capacity_extra=16, c_probes=4,
                           wal=WalConfig(dir=str(tmp_path / "wal")))
        srv = CFServer(R, cfg)
        results = srv.onboard_batch([R[i] for i in range(5)])
        assert all(r.ok for r in results)
        assert srv.wal.syncs == 1            # the whole batch: one fsync
        # recovery over the group-committed log is still bit-exact
        recovered = CFServer.recover(R, cfg)
        _assert_states_equal(recovered.state, srv.state)

    def test_group_commit_off_syncs_per_record(self, rng, tmp_path):
        R = make_ratings(rng, n=24, m=12)
        cfg = ServerConfig(capacity_extra=16, c_probes=4,
                           wal=WalConfig(dir=str(tmp_path / "wal"),
                                         group_commit=False))
        srv = CFServer(R, cfg)
        srv.onboard_batch([R[i] for i in range(5)])
        assert srv.wal.syncs == 5


class TestBatchedReplay:
    def _mutate(self, srv, R, fresh):
        """A mixed op stream: twin + traditional onboards (runs longer
        than the replay chunk), then add_ratings, then more onboards."""
        for i in range(6):
            assert srv.onboard_user(R[i]).ok
        for i in range(3):
            assert srv.onboard_user(fresh[i], use_twinsearch=False).ok
        for u, it, v in ((2, 1, 5.0), (0, 3, 4.0), (25, 2, 3.0),
                         (7, 5, 2.0), (1, 1, 1.0)):
            assert srv.add_rating(u, it, v)
        for i in range(3):
            assert srv.onboard_user(R[10 + i]).ok

    def test_batched_replay_bit_exact_vs_serial_and_live(self, rng,
                                                         tmp_path):
        R = make_ratings(rng, n=24, m=12)
        fresh = make_ratings(np.random.default_rng(55), n=4, m=12)

        def cfg(batch):
            # WAL only (no snapshot dir): recovery replays the full log
            # from a fresh build, and takes no truncating checkpoint, so
            # both recoveries see the same records.
            return ServerConfig(capacity_extra=16, c_probes=4,
                                wal=WalConfig(dir=str(tmp_path / "wal"),
                                              replay_batch=batch))

        live = CFServer(R, cfg(1))
        self._mutate(live, R, fresh)

        serial = CFServer.recover(R, cfg(1))
        batched = CFServer.recover(R, cfg(4))
        assert serial.stats.wal_replayed == batched.stats.wal_replayed == 17
        _assert_states_equal(serial.state, live.state)
        _assert_states_equal(batched.state, live.state)
        assert batched.stats.twin_hits == serial.stats.twin_hits
        assert batched.stats.fallbacks == serial.stats.fallbacks
        assert batched.stats.onboarded == serial.stats.onboarded
        # and both keep serving identically
        assert batched.recommend(3, n=5) == serial.recommend(3, n=5)

    def test_batched_replay_spans_rotation_records(self, rng, tmp_path):
        """Rotations break replay runs; the replayed geometry and state
        still land bit-exact with the live server."""
        R = make_ratings(rng, n=24, m=12)

        def cfg(batch):
            return ServerConfig(capacity_extra=4, c_probes=4,
                                wal=WalConfig(dir=str(tmp_path / "wal"),
                                              replay_batch=batch))

        live = CFServer(R, cfg(1))
        for i in range(11):                  # > capacity_extra: rotates
            assert live.onboard_user(R[i % 20]).ok
        assert live.stats.rotations >= 1

        batched = CFServer.recover(R, cfg(3))
        _assert_states_equal(batched.state, live.state)
        assert batched.n_base == live.n_base
        assert batched.stats.rotations == live.stats.rotations


# ---------------------------------------------------------------------------
# ServerConfig surface (api_redesign satellites)
# ---------------------------------------------------------------------------

class TestServerConfigShim:
    LEGACY = dict(capacity_extra=12, c_probes=5, sim_tol=1e-5,
                  measure="cosine", seed=3, rating_range=(1.0, 5.0),
                  quarantine_capacity=128, latency_window=256,
                  recover_after=16, shed_cooldown_s=0.5,
                  snapshot_every=32, snapshot_keep=2, check_every=4,
                  rotate_headroom=1.5, wal_fsync=False,
                  wal_group_commit=False, wal_replay_batch=8,
                  rotation_budget_rows=3, rotation_reserve_slots=2,
                  drain_on_shed=False)

    def test_kwargs_round_trip(self):
        cfg = ServerConfig.from_kwargs(**self.LEGACY)
        flat = cfg.to_kwargs()
        for key, val in self.LEGACY.items():
            assert flat[key] == val, key
        # and the flat form rebuilds the identical config
        assert ServerConfig.from_kwargs(**flat) == cfg

    def test_kwargs_map_into_sub_configs(self):
        cfg = ServerConfig.from_kwargs(**self.LEGACY)
        assert cfg.capacity_extra == 12
        assert cfg.snapshot.every == 32 and cfg.snapshot.check_every == 4
        assert cfg.wal.fsync is False and cfg.wal.replay_batch == 8
        assert cfg.rotation.headroom == 1.5
        assert cfg.rotation.budget_rows == 3
        assert cfg.rotation.reserve_slots == 2
        assert cfg.ladder.recover_after == 16
        assert cfg.ladder.drain_on_shed is False

    def test_unknown_kwarg_raises_type_error(self):
        with pytest.raises(TypeError, match="unexpected keyword"):
            ServerConfig.from_kwargs(no_such_knob=1)

    def test_legacy_kwargs_warn_and_match_config(self, rng):
        R = make_ratings(rng, n=20, m=10)
        with pytest.warns(DeprecationWarning, match="ServerConfig"):
            old = CFServer(R, capacity_extra=4, c_probes=4, seed=7)
        new = CFServer(R, ServerConfig(capacity_extra=4, c_probes=4,
                                       seed=7))
        _assert_states_equal(old.state, new.state)
        a = old.onboard_user(R[0])
        b = new.onboard_user(R[0])
        assert a.user_id == b.user_id and a.twin_found == b.twin_found

    def test_config_surface_does_not_warn(self, rng):
        R = make_ratings(rng, n=20, m=10)
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error", DeprecationWarning)
            CFServer(R, ServerConfig(capacity_extra=4, c_probes=4))

    def test_config_plus_legacy_kwargs_is_an_error(self, rng):
        R = make_ratings(rng, n=20, m=10)
        with pytest.raises(ValueError, match="not both"):
            CFServer(R, ServerConfig(), capacity_extra=4)
