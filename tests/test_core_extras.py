"""Gaussian bound analysis, incremental updates, list maintenance, kNN."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (build_state, knn, insert_into_lists, splice_twin,
                        SENTINEL_GATE)
from repro.core.gaussian import (empirical_max_sublist, empirical_set0,
                                 exact_fraction, paper_bound,
                                 paper_fraction)
from repro.core.similarity import cosine_matrix
from repro.core.update import add_rating, init_cache
from tests.conftest import make_ratings


class TestGaussian:
    def test_paper_constant_is_1_over_125(self):
        assert paper_fraction() == pytest.approx(1 / 125, rel=0.01)
        assert paper_bound(129_490) == pytest.approx(129_490 / 125, rel=0.01)

    def test_exact_fraction_bounds(self):
        # A narrow Gaussian concentrates mass -> bigger max sub-list.
        assert exact_fraction(0.5, 0.02) > exact_fraction(0.5, 0.3)
        assert 0 < exact_fraction(0.25, 0.25, x=100) < 1

    def test_empirical_sublist_on_gaussian(self, rng):
        vals = np.clip(rng.normal(0.3, 0.1, 20_000), 0, 1)
        got = empirical_max_sublist(vals, x=100)
        # max bin of N(0.3, 0.1) over width-0.01 bins ~ pdf(0.3)*0.01 ~ 4%
        assert 0.02 * 20_000 < got < 0.08 * 20_000

    def test_empirical_set0_monotone_in_probes(self, rng):
        R = make_ratings(rng, n=150, m=40)
        S = np.asarray(cosine_matrix(jnp.asarray(R)))
        probes = np.asarray([3, 40, 77, 120])
        s0 = S[probes, 9]
        sizes = [empirical_set0(S[probes[:c]], s0[:c], 1e-6)
                 for c in range(1, 5)]
        assert all(a >= b for a, b in zip(sizes, sizes[1:]))
        assert sizes[-1] >= 1                    # user 9 itself qualifies


class TestIncrementalUpdate:
    def test_add_rating_matches_rebuild(self, rng):
        R = make_ratings(rng, n=40, m=15)
        state = build_state(jnp.asarray(R))
        cache = init_cache(state.ratings)
        state2, cache2 = add_rating(state, cache, jnp.int32(7),
                                    jnp.int32(3), jnp.float32(5.0))
        R2 = R.copy()
        R2[7, 3] = 5.0
        ref = build_state(jnp.asarray(R2))
        np.testing.assert_allclose(np.asarray(state2.sim_vals[7]),
                                   np.asarray(ref.sim_vals[7]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(cache2.dots),
                                   np.asarray(R2.astype(np.float64) @
                                              R2.T.astype(np.float64)),
                                   atol=1e-2)

    def test_remove_rating(self, rng):
        R = make_ratings(rng, n=30, m=12)
        R[5, 2] = 4.0
        state = build_state(jnp.asarray(R))
        cache = init_cache(state.ratings)
        state2, _ = add_rating(state, cache, jnp.int32(5), jnp.int32(2),
                               jnp.float32(0.0))
        assert float(state2.ratings[5, 2]) == 0.0


class TestMaintenance:
    def test_insert_matches_rebuild(self, rng):
        R = make_ratings(rng, n=30, m=12)
        k = 1
        state = build_state(jnp.asarray(R), capacity_extra=k)
        r0 = R[4].copy()
        from repro.core import baseline
        vals, idx, sims = baseline.build_list(state, jnp.asarray(r0))
        state2 = baseline.append_user(state, jnp.asarray(r0), vals, idx)
        state3 = insert_into_lists(state2, jnp.int32(30), sims)
        # Every old user's list now contains user 30 with the right sim.
        R_full = np.concatenate([R, r0[None]], axis=0)
        ref = build_state(jnp.asarray(R_full))
        for u in (0, 7, 19):
            # the insert consumed the one sentinel slot: rows align exactly
            got = np.asarray(state3.sim_vals[u])
            want = np.asarray(ref.sim_vals[u])
            np.testing.assert_allclose(got, want, atol=1e-5)

    def test_splice_twin_equals_insert(self, rng):
        R = make_ratings(rng, n=25, m=10)
        state = build_state(jnp.asarray(R), capacity_extra=1)
        r0 = R[6].copy()                        # exact twin of user 6
        from repro.core import baseline
        vals, idx, sims = baseline.build_list(state, jnp.asarray(r0))
        st = baseline.append_user(state, jnp.asarray(r0), vals, idx)
        a = insert_into_lists(st, jnp.int32(25), sims)
        b = splice_twin(st, jnp.int32(25), jnp.int32(6))
        for u in (0, 10, 20):
            np.testing.assert_allclose(np.asarray(a.sim_vals[u]),
                                       np.asarray(b.sim_vals[u]), atol=1e-5)


class TestKNN:
    def test_top_k_excludes_self(self, rng):
        R = make_ratings(rng)
        state = build_state(jnp.asarray(R))
        sims, nbrs = knn.top_k_neighbors(state, jnp.int32(5), 10)
        assert 5 not in np.asarray(nbrs)
        assert bool(jnp.all(sims > SENTINEL_GATE))

    def test_predict_in_range(self, rng):
        R = make_ratings(rng)
        state = build_state(jnp.asarray(R))
        p = knn.predict(state, jnp.int32(3), jnp.int32(7), k=10)
        assert 0.0 <= float(p) <= 5.0

    def test_recommend_unseen_only(self, rng):
        R = make_ratings(rng)
        state = build_state(jnp.asarray(R))
        scores, items = knn.recommend(state, jnp.int32(2), n_rec=5)
        for it in np.asarray(items):
            assert R[2, it] == 0
