"""The kNN-attack scenario (Calandrino et al.) the paper cites as its
motivating special case: an attacker injects k identical fake profiles
(>= 8 rated items) to surround a target user.  The demo shows (a) the
system-load angle — TwinSearch makes onboarding the flood ~free instead of
k full rebuilds — and (b) twin detection as a *defence* signal (a burst of
exact twins is anomalous).

Run:  PYTHONPATH=src python examples/knn_attack_demo.py
"""
import numpy as np

from repro.data import plant_twins, synth_ratings
from repro.serving import CFServer, ServerConfig


def main() -> None:
    R = synth_ratings(0, 1500, 600, 60_000)
    srv = CFServer(R, ServerConfig(capacity_extra=64, c_probes=8))

    print("== attacker injects k=30 identical fake users")
    attack = plant_twins(R, 30, source_user=None, seed=13)
    twin_flags = []
    for i in range(30):
        res = srv.onboard_user(attack[i])
        twin_flags.append(res.twin_found)

    s = srv.stats.summary()
    print(f"   onboarding cost: {s['fallbacks']} full build(s) + "
          f"{s['twin_hits']} list copies "
          f"(traditional: 30 full builds)")

    # Defence signal: consecutive exact-twin onboards
    streak = 0
    best = 0
    for f in twin_flags:
        streak = streak + 1 if f else 0
        best = max(best, streak)
    print(f"   longest exact-twin onboarding streak: {best} "
          f"(threshold-alarm material — organic traffic almost never "
          f"produces long exact-duplicate runs)")

    # The attack profile's neighbourhood is now all fakes (query the last
    # fake: its copied-and-patched list covers the whole burst):
    last = int(srv.state.n_active) - 1
    sims, nbrs = __import__("repro.core", fromlist=["knn"]).knn \
        .top_k_neighbors(srv.state, last, 10)
    n_fake = int(np.sum(np.asarray(nbrs) >= 1500))
    print(f"   fake user #{last}'s top-10 neighbours: {n_fake}/10 are "
          f"fellow fakes (sim=1.0) — the mechanism the attack exploits")


if __name__ == "__main__":
    main()
