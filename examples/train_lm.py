"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic bigram corpus, with checkpointing + resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dim 512]

The config is a shrunk Gemma-3-style model (~100M params at the defaults);
loss should fall from ~ln(V) toward the bigram entropy floor.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.data import TokenPipeline
from repro.models import transformer as lm
from repro.training import (AdamW, TrainLoopConfig, make_train_step,
                            run_loop, warmup_cosine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = LMConfig(
        name="train-demo", n_layers=args.layers, d_model=args.dim,
        n_heads=8, n_kv_heads=4, head_dim=args.dim // 8, d_ff=4 * args.dim,
        vocab_size=args.vocab, act="swiglu", window=128, global_every=4,
        dtype="float32")
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} V={cfg.vocab_size})")

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pipe = TokenPipeline(args.vocab, args.batch, args.seq, seed=0)

    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps), weight_decay=0.01)
    raw_step = make_train_step(
        lambda p, b: lm.lm_loss(p, b["tokens"], cfg, loss_chunk=128), opt)
    step = jax.jit(raw_step, donate_argnums=(0, 1))

    def batches(i: int) -> dict:
        return {"tokens": jnp.asarray(pipe(i)["tokens"])}

    loop_cfg = TrainLoopConfig(n_steps=args.steps, ckpt_dir=args.ckpt,
                               ckpt_every=100, log_every=10)
    from repro.training import init_ef
    params, _, hist = run_loop(step, params, opt.init(params), batches,
                               loop_cfg, ef_state=init_ef(params),
                               data_state_fn=pipe.state)
    print(f"loss: {hist[0]:.3f} -> {hist[-1]:.3f} over {len(hist)} steps")
    assert hist[-1] < hist[0], "training failed to reduce loss"


if __name__ == "__main__":
    import logging
    logging.basicConfig(level=logging.INFO)
    main()
