"""Quickstart: neighbourhood CF with TwinSearch new-user onboarding.

Builds a MovieLens-100k-scale system, onboards a burst of identical new
users (the paper's special case / kNN-attack scenario), and shows the
TwinSearch fast path against the traditional rebuild.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.data import movielens_100k, plant_twins
from repro.serving import CFServer, ServerConfig

def main() -> None:
    print("== building MovieLens-scale CF system (943 users x 1682 films)")
    R = movielens_100k(seed=0)
    t0 = time.perf_counter()
    srv = CFServer(R, ServerConfig(capacity_extra=32, c_probes=8))
    print(f"   full similarity build: {time.perf_counter() - t0:.2f}s")

    print("== kNN-attack burst: 10 identical new users (>=8 ratings)")
    burst = plant_twins(R, 10, source_user=None, seed=7)
    for i in range(10):
        res = srv.onboard_user(burst[i])
        path = "TwinSearch copy" if res.twin_found else "full build"
        print(f"   user {res.user_id}: {path:15s} {res.latency_ms:7.1f}ms")
    s = srv.stats.summary()
    print(f"   twin hits: {s['twin_hits']}/10, fallbacks {s['fallbacks']}, "
          f"p50 {s['onboard_p50_ms']:.1f}ms")

    print("== the copied lists serve recommendations immediately")
    recs = srv.recommend(943, n=5)           # first onboarded user
    print("   top-5 films for new user 943:",
          [f"#{i}({s:.2f})" for i, s in recs])

    print("== baseline comparison: same burst, traditional path only")
    srv2 = CFServer(R, ServerConfig(capacity_extra=32))
    for i in range(10):
        srv2.onboard_user(burst[i], use_twinsearch=False)
    med = lambda xs: sorted(xs)[len(xs) // 2]            # noqa: E731
    # steady-state medians (first call on each path pays jit compile)
    t_tw = med(list(srv.stats.onboard_ms)[1:])
    t_tr = med(list(srv2.stats.onboard_ms)[1:])
    print(f"   per-user p50: traditional {t_tr:.1f}ms vs twinsearch "
          f"{t_tw:.1f}ms ({t_tr / max(t_tw, 1e-9):.1f}x)")
    print("   (MovieLens is small — the gap grows with n·m; see "
          "benchmarks/ for the Douban-scale and dry-run numbers)")


if __name__ == "__main__":
    main()
