"""Serving scenario: a live CF recommendation service handling a mixed
request stream — onboarding (with duplicate-heavy traffic), rating updates
(incremental similarity maintenance), and recommendation queries.

Run:  PYTHONPATH=src python examples/serve_recs.py
"""
import time

import numpy as np

from repro.data import synth_ratings
from repro.serving import CFServer, RotationConfig, ServerConfig


def main() -> None:
    rng = np.random.default_rng(0)
    R = synth_ratings(0, 2000, 800, 90_000)
    print("== boot: 2000-user, 800-item system")
    srv = CFServer(R, ServerConfig(capacity_extra=64, c_probes=8,
                                   rotation=RotationConfig(budget_rows=256)))

    print("== mixed request stream (200 requests)")
    t0 = time.perf_counter()
    n_q = n_u = 0
    onboard_pool = [None, 17, 17, None, 42]      # duplicate-heavy
    for i in range(200):
        kind = rng.random()
        if kind < 0.1 and srv.stats.onboarded < 60:
            src = onboard_pool[srv.stats.onboarded % len(onboard_pool)]
            row = (R[src] if src is not None else
                   synth_ratings(100 + i, 1, 800, 40)[0])
            res = srv.onboard_user(row)
            assert res.ok and res.rung == "twinsearch"
        elif kind < 0.3:
            srv.add_rating(int(rng.integers(0, 2000)),
                           int(rng.integers(0, 800)),
                           float(rng.integers(1, 6)))
            n_u += 1
        else:
            srv.recommend(int(rng.integers(0, 2000)), n=10)
            n_q += 1
    dt = time.perf_counter() - t0
    s = srv.stats.summary()
    print(f"   {n_q} queries, {n_u} rating updates, "
          f"{s['onboarded']} onboards in {dt:.2f}s")
    print(f"   onboarding: {s['twin_hits']} twin hits / "
          f"{s['fallbacks']} full builds "
          f"(p50 {s['onboard_p50_ms']:.1f}ms, "
          f"p99 {s['onboard_p99_ms']:.1f}ms)")
    hit_rate = s["twin_hits"] / max(s["onboarded"], 1)
    print(f"   twin-hit rate {hit_rate:.0%} — duplicate-heavy onboarding "
          f"traffic is the paper's regime")


if __name__ == "__main__":
    main()
